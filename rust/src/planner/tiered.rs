//! K-tier generalization of Algorithm 1: the equal-marginal-cost condition
//! the paper derives for a single boundary extends naturally to K − 1
//! boundaries, each with its own Compress-and-Route band.
//!
//! A [`FleetSpec`] orders K tiers by context window; tier `i < K-1` serves
//! `L_total <= B_i` (window = boundary), each boundary `B_i` carries a
//! compression bandwidth `gamma_i` whose band `(B_i, gamma_i B_i]`
//! compresses *down into tier i*, and the last tier takes the residual.
//! Every tier is sized by the same restricted-distribution Erlang-C
//! inversion as the paper's two pools, with the same post-compression
//! recalibration (§6 "Critical") applied per boundary: tier `i`'s service
//! distribution is `F` restricted to `(gamma_{i-1} B_{i-1}, B_i]`.
//!
//! **Invariant:** with K = 2 this module *is* the two-pool planner —
//! [`plan_tiers`] performs bit-for-bit the computation of the pre-refactor
//! `plan_cell`, and `planner::sweep` routes `plan_fleet`/`sweep_full`
//! through it (property-tested in `tests/tier_equivalence.rs`).

use crate::config::{FleetSpec, SkuCatalog};
use crate::planner::cost::fleet_cost_yr_tiered;
use crate::planner::sizing::{min_gpus, SizingError};
use crate::planner::sweep::{
    calibrated, candidate_boundaries, par_map, CalibCache, Plan, PlanInput, PoolPlan,
};
use crate::queueing::service::{CutMoments, MomentTable, ServiceStats};
use crate::util::par::par_map_strided;
use crate::workload::cdf::LengthDist;

/// A provisioned K-tier fleet: the generalized planner's output tuple.
#[derive(Clone, Debug)]
pub struct TieredPlan {
    /// The fleet shape this plan provisions (windows, slots, $/hr).
    pub spec: FleetSpec,
    /// Effective per-boundary compression bandwidths (clamped so no band
    /// crosses the next boundary up).
    pub gammas: Vec<f64>,
    /// `F(B_i)` at each boundary (cumulative natural share below it).
    pub nat_below: Vec<f64>,
    /// Borderline band fraction `F(gamma_i B_i) − F(B_i)` per boundary.
    pub betas: Vec<f64>,
    /// Compressed share moved down across each boundary: `beta_i * p_c`.
    pub gains: Vec<f64>,
    /// One sized pool per tier, in tier order.
    pub tiers: Vec<PoolPlan>,
    pub cost_yr: f64,
}

impl TieredPlan {
    pub fn k(&self) -> usize {
        self.tiers.len()
    }

    pub fn total_gpus(&self) -> u64 {
        self.tiers.iter().map(|t| t.n_gpus).sum()
    }

    pub fn boundaries(&self) -> Vec<u32> {
        self.spec.boundaries()
    }

    pub fn gpu_counts(&self) -> Vec<u64> {
        self.tiers.iter().map(|t| t.n_gpus).collect()
    }

    /// Project a K = 2 plan into the paper's two-pool [`Plan`] shape
    /// (consumes the tier vector; all scalar fields are the exact values
    /// the pre-refactor planner produced).
    pub fn into_two_pool(mut self) -> Plan {
        assert_eq!(self.tiers.len(), 2, "into_two_pool needs K = 2");
        let long = self.tiers.pop().expect("long tier");
        let short = self.tiers.pop().expect("short tier");
        let alpha = self.nat_below[0];
        Plan {
            b_short: self.spec.tiers[0].c_max,
            gamma: self.gammas[0],
            alpha,
            beta: self.betas[0],
            alpha_prime: alpha + self.gains[0],
            short,
            long,
            cost_yr: self.cost_yr,
        }
    }
}

/// Size a K-tier fleet at fixed boundaries and per-boundary gammas
/// (Algorithm 1 generalized; one cell of [`sweep_tiered`]).
///
/// Traffic shares: tier `i` receives its natural range `(B_{i-1}, B_i]`
/// plus the compressed fraction of its own band `(B_i, gamma_i B_i] * p_c`
/// minus the fraction compressed down across `B_{i-1}`; the last tier's
/// rate is the exact residual `lambda − sum(lower tiers)`, matching the
/// two-pool `lambda_l = lambda − lambda_s` bit-for-bit at K = 2.
///
/// Approximation note (K >= 3): the workload's `p_c` is calibrated at its
/// own evaluation band; this planner applies it at *every* boundary, while
/// the DES/gateway realize per-band compressibility from category
/// sampling. At K = 2 the two coincide exactly (same band); at K >= 3 the
/// planner's mid-tier loads are a `p_c`-uniform approximation of the
/// routed traffic.
pub fn plan_tiers(
    input: &PlanInput,
    spec: &FleetSpec,
    gammas: &[f64],
    recalibrate: bool,
    cache: Option<&CalibCache>,
) -> Result<TieredPlan, SizingError> {
    let layout = cell_layout(input, spec, gammas, recalibrate);

    // Erlang-C inversion for one sized tier (shared by every branch so the
    // K = 2 path stays call-for-call identical to the pre-refactor code).
    // Each tier sizes against its own P99 TTFT target when the spec sets
    // one; the `None` default inherits the fleet SLO, making global-SLO
    // configs bit-identical to the pre-refactor planner.
    let size = |lambda_i: f64, svc: ServiceStats, slo_s: f64| -> Result<PoolPlan, SizingError> {
        Ok(PoolPlan {
            n_gpus: min_gpus(
                lambda_i,
                &svc,
                slo_s,
                input.cfg.rho_max,
                input.strict_slo,
            )?,
            lambda: lambda_i,
            svc: Some(svc),
        })
    };

    let k = spec.k();
    let mut tiers = Vec::with_capacity(k);
    let mut counts = Vec::with_capacity(k);
    for (i, &(lambda_i, cut)) in layout.tiers.iter().enumerate() {
        let t = &spec.tiers[i];
        let tier_slo = t.slo_or(input.slo.p99_ttft_s);
        let pool = match cut {
            Some((lo, hi)) => {
                // Base-rate calibration (SKU-independent, so the cache
                // stays keyed by cut and slot count alone), then the
                // tier's SKU rate multiplier as a uniform time dilation.
                // `scaled_mu(1.0)` is the identity, so single-SKU tiers
                // are sized bit-identically to the pre-catalog planner.
                let svc = calibrated(input, cache, lo, hi, t.n_max).scaled_mu(t.mu_scale());
                let mut pool = size(lambda_i, svc, tier_slo)?;
                // KV stability floor (closed-form, Little's law over
                // full-residency reservations): the Erlang-C count alone
                // can leave `rho_kv >= rho_max` on decode-heavy traffic.
                // `kv: None` (the default) skips this — bit-identical to
                // the KV-unconstrained planner.
                if let Some(policy) = input.kv {
                    pool.n_gpus = pool.n_gpus.max(tier_kv_floor(
                        input, policy, lambda_i, lo, hi, t.n_max, t.c_max, t.mu_scale(),
                    ));
                }
                // N+k survivability: k spares on top of the sized count,
                // so the tier still meets its SLO with k machines down.
                // k = 0 (the default) adds nothing — bit-identical.
                pool.n_gpus += tier_redundancy(input, i);
                pool
            }
            None => PoolPlan::empty(),
        };
        counts.push(pool.n_gpus);
        tiers.push(pool);
    }

    let rates: Vec<f64> = spec.tiers.iter().map(|t| t.cost_hr).collect();
    Ok(TieredPlan {
        spec: spec.clone(),
        gammas: layout.eff,
        nat_below: layout.nat_below,
        betas: layout.betas,
        gains: layout.gains,
        cost_yr: fleet_cost_yr_tiered(&counts, &rates),
        tiers,
    })
}

/// The cheap (no-quadrature, no-Erlang) prefix of [`plan_tiers`]: clamped
/// gammas, boundary shares, per-tier arrival rates and calibration cuts.
/// One definition shared by the exact cell evaluation and the
/// bound-and-prune cost bound, so the two can never disagree on a cell's
/// traffic split or truncation cuts — the bound's soundness rests on it.
#[derive(Default)]
pub(crate) struct CellLayout {
    /// Effective per-boundary gammas (band clamped at the next boundary).
    pub eff: Vec<f64>,
    /// `F(B_i)` per boundary.
    pub nat_below: Vec<f64>,
    /// Borderline band fraction per boundary.
    pub betas: Vec<f64>,
    /// Compressed share moved down per boundary (`beta_i * p_c`).
    pub gains: Vec<f64>,
    /// Per tier: arrival rate and the calibration cut `(lo, hi]`;
    /// `None` = the tier is left unprovisioned ([`PoolPlan::empty`]).
    pub tiers: Vec<(f64, Option<(f64, f64)>)>,
}

pub(crate) fn cell_layout(
    input: &PlanInput,
    spec: &FleetSpec,
    gammas: &[f64],
    recalibrate: bool,
) -> CellLayout {
    let mut out = CellLayout::default();
    cell_layout_into(input, spec, gammas, recalibrate, &mut out);
    out
}

/// [`cell_layout`] writing into caller-recycled buffers: the batched
/// bound pass reuses one `CellLayout` per lane across every block it
/// scores, so its steady-state layout work allocates (almost) nothing.
/// Same single definition — the allocating wrapper above is the only
/// other entry point.
pub(crate) fn cell_layout_into(
    input: &PlanInput,
    spec: &FleetSpec,
    gammas: &[f64],
    recalibrate: bool,
    out: &mut CellLayout,
) {
    let k = spec.k();
    assert!(k >= 2, "plan_tiers needs at least 2 tiers");
    assert_eq!(gammas.len(), k - 1, "one gamma per boundary");
    let w = &input.workload;
    let min_t = w.cdf.min_tokens();
    let max_t = w.cdf.max_tokens();
    let boundaries = spec.boundaries();

    // Effective gammas: a boundary's band may not cross the next boundary
    // up — traffic in `(B_{i+1}, gamma_i B_i]` would otherwise skip a tier
    // and the share accounting below (adjacent-tier transfers only) would
    // not match the router. The last boundary is unclamped, so K = 2 is
    // Algorithm 1 verbatim.
    out.eff.clear();
    for (i, &g_i) in gammas.iter().enumerate() {
        assert!(g_i >= 1.0);
        out.eff.push(crate::compress::gate::clamp_gamma(
            boundaries[i],
            boundaries.get(i + 1).copied(),
            g_i,
        ));
    }

    out.nat_below.clear();
    out.betas.clear();
    out.gains.clear();
    for i in 0..k - 1 {
        let b = boundaries[i] as f64;
        let alpha_i = w.cdf.cdf(b);
        let beta_i = w.cdf.cdf(out.eff[i] * b) - alpha_i;
        // Eq. 1: only an open band (gamma > 1) compresses.
        let p_c = if out.eff[i] > 1.0 { w.p_c } else { 0.0 };
        out.nat_below.push(alpha_i);
        out.betas.push(beta_i);
        out.gains.push(beta_i * p_c);
    }

    out.tiers.clear();
    let mut lambda_used = 0.0;
    for i in 0..k {
        let last = i + 1 == k;
        // Lower calibration cut: the post-compression residual above the
        // boundary below (§6 recalibration), or the raw boundary in the
        // no-recalibration ablation.
        let cut_prev = if i == 0 {
            min_t
        } else {
            let bp = boundaries[i - 1] as f64;
            if recalibrate {
                out.eff[i - 1] * bp
            } else {
                bp
            }
        };
        let lo_f = if i == 0 { 0.0 } else { out.nat_below[i - 1] };
        let loss = if i == 0 { 0.0 } else { out.gains[i - 1] };

        if last {
            let lambda_i = input.lambda - lambda_used;
            let cut = if lambda_i > input.lambda * 1e-9 && w.cdf.cdf(cut_prev) < 1.0 - 1e-12 {
                Some((cut_prev.max(min_t), max_t))
            } else {
                None
            };
            out.tiers.push((lambda_i, cut));
        } else {
            let nat = out.nat_below[i] - lo_f;
            let share = ((out.nat_below[i] - lo_f) + out.gains[i]) - loss;
            let lambda_i = share * input.lambda;
            lambda_used += lambda_i;
            let b = boundaries[i] as f64;
            let hi = b.min(max_t);
            let cut = if i == 0 {
                // Bit-for-bit the pre-refactor short pool: calibrate from
                // F restricted to [min, B] whenever it has natural mass.
                if lambda_i > 0.0 && nat > 0.0 {
                    Some((min_t, hi))
                } else {
                    None
                }
            } else if lambda_i > 0.0 {
                // Middle tier: the widest-information calibration range
                // that still has mass. A fully-clamped band can compress
                // the entire post-compression residual away, and a flat
                // CDF segment can empty the natural range too; a tier
                // that still receives traffic must be provisioned, so
                // fall back — last to the boundary's own band, where its
                // compressed arrivals originate (pre-compression lengths:
                // a conservative stand-in for the post-compression mix).
                let has_mass = |lo: f64| lo < hi && w.cdf.cdf(lo) < w.cdf.cdf(hi) - 1e-12;
                let lo_recal = cut_prev.max(min_t);
                let lo_nat = (boundaries[i - 1] as f64).max(min_t);
                if has_mass(lo_recal) {
                    Some((lo_recal, hi))
                } else if has_mass(lo_nat) {
                    Some((lo_nat, hi))
                } else if has_mass(min_t) {
                    Some((min_t, hi))
                } else {
                    // lambda_i > 0 with no mass below B_i forces
                    // gains[i] > 0, so the band (B_i, gamma_i B_i] has
                    // mass by construction.
                    Some((b.max(min_t), (out.eff[i] * b).min(max_t)))
                }
            } else {
                None
            };
            out.tiers.push((lambda_i, cut));
        }
    }
}

/// One evaluated cell of the K-tier sweep grid.
#[derive(Clone, Debug, PartialEq)]
pub struct TierCell {
    pub boundaries: Vec<u32>,
    pub gamma: f64,
    pub cost_yr: f64,
}

/// Ascending `choose`-combinations of the candidate boundary grid.
pub(crate) fn boundary_combos(cands: &[u32], choose: usize) -> Vec<Vec<u32>> {
    fn rec(cands: &[u32], need: usize, start: usize, cur: &mut Vec<u32>, out: &mut Vec<Vec<u32>>) {
        if need == 0 {
            out.push(cur.clone());
            return;
        }
        if start + need > cands.len() {
            return;
        }
        for i in start..=cands.len() - need {
            cur.push(cands[i]);
            rec(cands, need - 1, i + 1, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(cands, choose, 0, &mut Vec::with_capacity(choose), &mut out);
    out
}

/// Every per-tier SKU assignment for a K-tier fleet over a catalog of
/// `s` SKUs: `s^k` rows, lexicographic with the last tier fastest-
/// varying. The catalog-of-one space is the single all-zero row, which
/// is how the SKU-generalized sweep degenerates onto the plain grid.
pub fn sku_assignments(s: usize, k: usize) -> Vec<Vec<usize>> {
    assert!(s >= 1 && k >= 1, "need a non-empty catalog and >= 1 tier");
    let mut out = Vec::with_capacity(s.saturating_pow(k as u32));
    let mut cur = vec![0usize; k];
    'rows: loop {
        out.push(cur.clone());
        // Odometer increment, last digit fastest.
        let mut i = k;
        while i > 0 {
            i -= 1;
            cur[i] += 1;
            if cur[i] < s {
                continue 'rows;
            }
            cur[i] = 0;
        }
        return out;
    }
}

/// Cell count of the SKU-generalized sweep grid for `k` tiers over
/// `catalog`: boundary combos x gammas x per-tier SKU assignments
/// (saturating — the whole point is that this overflows usefulness long
/// before it overflows usize). The anytime optimizer compares it against
/// its exhaustive budget to decide whether the exact oracle is
/// affordable.
pub fn sku_sweep_space(input: &PlanInput, k: usize, catalog: &SkuCatalog) -> usize {
    assert!(k >= 2, "sku_sweep_space needs at least 2 tiers");
    let cands = candidate_boundaries(input);
    boundary_combos(&cands, k - 1)
        .len()
        .saturating_mul(input.cfg.gammas.len())
        .saturating_mul(catalog.len().saturating_pow(k as u32))
}

/// Full K-tier Algorithm-1 sweep: every ascending (K−1)-subset of the
/// candidate boundary grid crossed with the shared gamma grid (one gamma
/// applied at every boundary, clamped per boundary by [`plan_tiers`]).
/// Cells are sharded over scoped threads against one merged
/// [`CalibCache`]; infeasible cells are skipped. Ties break toward earlier
/// grid cells exactly as in `sweep_full`, and for K = 2 the selected
/// optimum is bit-identical to `sweep_full`'s (tested).
pub fn sweep_tiered(
    input: &PlanInput,
    k: usize,
) -> Result<(TieredPlan, Vec<TierCell>), SizingError> {
    sweep_tiered_with(input, k, true)
}

/// Single-threaded [`sweep_tiered`] (equivalence oracle / small hosts).
pub fn sweep_tiered_serial(
    input: &PlanInput,
    k: usize,
) -> Result<(TieredPlan, Vec<TierCell>), SizingError> {
    sweep_tiered_with(input, k, false)
}

/// [`sweep_tiered`] warm-started from a caller-owned [`CalibCache`] — the
/// online replanner's path: calibrations survive across epochs, so a
/// re-sweep under a drifted rate (same CDF snapshot) touches only the
/// cells whose truncation cuts actually changed. Results are bit-identical
/// to [`sweep_tiered`] (the cache only memoizes deterministic values).
pub fn sweep_tiered_cached(
    input: &PlanInput,
    k: usize,
    cache: &CalibCache,
) -> Result<(TieredPlan, Vec<TierCell>), SizingError> {
    sweep_tiered_impl(input, k, true, cache)
}

fn sweep_tiered_with(
    input: &PlanInput,
    k: usize,
    parallel: bool,
) -> Result<(TieredPlan, Vec<TierCell>), SizingError> {
    sweep_tiered_impl(input, k, parallel, &CalibCache::new())
}

fn sweep_tiered_impl(
    input: &PlanInput,
    k: usize,
    parallel: bool,
    cache: &CalibCache,
) -> Result<(TieredPlan, Vec<TierCell>), SizingError> {
    assert!(k >= 2, "sweep_tiered needs at least 2 tiers");
    let cands = candidate_boundaries(input);
    let combos = boundary_combos(&cands, k - 1);
    if combos.is_empty() {
        return Err(SizingError::NoFeasibleTiering { k });
    }
    let mut cells: Vec<(&[u32], f64)> = Vec::with_capacity(combos.len() * input.cfg.gammas.len());
    for combo in &combos {
        for &gamma in &input.cfg.gammas {
            cells.push((combo.as_slice(), gamma));
        }
    }
    let plans = par_map(&cells, parallel, |&(combo, gamma)| {
        let spec = input.gpu.fleet_spec(combo);
        Ok(plan_tiers(input, &spec, &vec![gamma; k - 1], true, Some(cache)).ok())
    })?;

    let mut grid = Vec::with_capacity(cells.len());
    let mut best: Option<TieredPlan> = None;
    for (&(combo, gamma), plan) in cells.iter().zip(plans) {
        let Some(plan) = plan else { continue };
        grid.push(TierCell {
            boundaries: combo.to_vec(),
            gamma,
            cost_yr: plan.cost_yr,
        });
        let better = match &best {
            None => true,
            Some(b) => plan.cost_yr < b.cost_yr - 1e-9,
        };
        if better {
            best = Some(plan);
        }
    }
    let best = best.ok_or(SizingError::NoFeasibleTiering { k })?;
    Ok((best, grid))
}

/// Telemetry of one bound-and-prune sweep ([`sweep_tiered_pruned`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct PruneStats {
    /// Grid cells in the sweep.
    pub cells: usize,
    /// Cells skipped because their closed-form cost lower bound already
    /// exceeded an exactly-evaluated incumbent.
    pub pruned: usize,
    /// Cells evaluated exactly (quadrature + Erlang inversion).
    pub evaluated: usize,
    /// Evaluated cells that turned out infeasible.
    pub infeasible: usize,
    /// Incumbent-seeding evaluations (caller seeds + cheapest-bound cell).
    pub seeded: usize,
}

impl PruneStats {
    /// Fraction of grid cells pruned (the bench's headline number).
    pub fn pruned_frac(&self) -> f64 {
        if self.cells == 0 {
            0.0
        } else {
            self.pruned as f64 / self.cells as f64
        }
    }
}

/// A pruned cell must be worse than the incumbent by at least this much
/// ($/yr) — dwarfs the selection rule's 1e-9 tie band (so pruning can
/// never flip a tie) while being far below one GPU-hour.
const PRUNE_MARGIN: f64 = 1.0;

/// Closed-form lower bound on one cell's annual cost: per tier, the
/// stability bound `n_i >= ceil(a_i / rho_max)` priced at the tier rates —
/// no Erlang-C, no quadrature. `a_i` uses the moment table's
/// error-adjusted `E[S]` lower bound, so the result provably bounds the
/// quadrature-evaluated cost from below (the SLO constraint only ever
/// *adds* GPUs, and infeasible cells are skipped by the sweep anyway;
/// likewise the KV stability floor of [`PlanInput::kv`] only ever
/// *raises* a tier's exact count, so this KV-blind bound stays
/// admissible unchanged). `None` when a cut cannot be bounded (the cell
/// is then evaluated). The cut moments come through `cut` so the batched
/// evaluator can route the identical arithmetic through its
/// [`CutMemo`]-backed source.
///
/// [`CutMemo`]: crate::queueing::simd::cells::CutMemo
/// Per-iteration latency of tier `i` under its SKU rate multiplier. The
/// `== 1.0` arm returns the base value untouched — not `base / 1.0` — so
/// the plain grid's bounds stay bit-identical by construction rather than
/// by IEEE accident. Mirrors `scaled_mu` on the exact path: both divide
/// every base-rate time quantity by `mu_scale`, so the bound's soundness
/// argument carries over per SKU.
fn tier_t_iter_s(input: &PlanInput, spec: &FleetSpec, i: usize) -> f64 {
    let t = &spec.tiers[i];
    let base = input.gpu.t_iter_s(t.n_max);
    let ms = t.mu_scale();
    if ms == 1.0 {
        base
    } else {
        base / ms
    }
}

/// Tier `i`'s KV-stability GPU floor: the smallest count keeping
/// `rho_kv = lambda_i * E[(l_in + l_out) * T] * t_iter / (n * cap)` below
/// `rho_max`, with the tier's per-GPU capacity
/// `cap_frac * n_max * c_max` tokens and the KV load integrated over the
/// *same* truncated distribution and quadrature grids as the tier's
/// service calibration (so the analytical boundary and the DES agree —
/// Table 12). The SKU rate multiplier dilates iteration time exactly as
/// in [`calibrated`].
#[allow(clippy::too_many_arguments)]
fn tier_kv_floor(
    input: &PlanInput,
    policy: crate::queueing::kv::KvPlanPolicy,
    lambda_i: f64,
    lo: f64,
    hi: f64,
    n_slots: u32,
    c_max: u32,
    mu_scale: f64,
) -> u64 {
    use crate::workload::cdf::TruncatedDist;
    let w = &input.workload;
    let dist = TruncatedDist::new(w.cdf.clone(), lo, hi);
    let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
    let kv = crate::queueing::kv::calibrate_kv_quadrature(
        &dist, &w.output, &input.gpu, n_slots, len_points, 8,
    )
    .scaled_mu(mu_scale);
    let cap = policy.cap_tokens(n_slots, c_max);
    crate::queueing::kv::min_gpus_kv(lambda_i, cap, input.cfg.rho_max, &kv)
}

/// Tier `t`'s N+k spare count from [`PlanInput::redundancy`]: empty means
/// 0 everywhere (the bit-identical default), a single entry broadcasts to
/// every tier, anything longer is per-tier (missing trailing entries are
/// 0). Shared by the exact evaluation and both bound paths so the spares
/// are priced identically everywhere and pruning stays exact.
pub(crate) fn tier_redundancy(input: &PlanInput, t: usize) -> u64 {
    match input.redundancy.as_slice() {
        [] => 0,
        [k] => *k,
        ks => ks.get(t).copied().unwrap_or(0),
    }
}

fn cell_cost_lb_with(
    input: &PlanInput,
    spec: &FleetSpec,
    gammas: &[f64],
    cut: &mut dyn FnMut(f64, f64) -> Option<CutMoments>,
) -> Option<f64> {
    let layout = cell_layout(input, spec, gammas, true);
    let mut counts = Vec::with_capacity(spec.k());
    for (i, &(lambda_i, cut_i)) in layout.tiers.iter().enumerate() {
        let n_lb = match cut_i {
            Some((lo, hi)) if lambda_i > 0.0 => {
                let m = cut(lo, hi)?;
                // Iterations >= 2 always (one prefill chunk + one decode).
                let e_iter_lb = (m.e_iter - m.err_iter).max(1.0);
                let n_slots = spec.tiers[i].n_max;
                let e_s_lb = e_iter_lb * tier_t_iter_s(input, spec, i);
                let a_lb = lambda_i * e_s_lb / n_slots as f64;
                // N+k spares are a constant add on every provisioned
                // tier, on the bound exactly as on the exact path — the
                // bound-gap argument is unchanged.
                (a_lb / input.cfg.rho_max).ceil().max(1.0) as u64 + tier_redundancy(input, i)
            }
            _ => 0,
        };
        counts.push(n_lb);
    }
    let rates: Vec<f64> = spec.tiers.iter().map(|t| t.cost_hr).collect();
    Some(fleet_cost_yr_tiered(&counts, &rates))
}

/// [`cell_cost_lb_with`] reading cut moments straight off the table.
/// `pub(crate)` for the anytime optimizer's frontier ordering and its
/// reported bound gap.
pub(crate) fn cell_cost_lb(
    input: &PlanInput,
    spec: &FleetSpec,
    gammas: &[f64],
    table: &MomentTable,
    len_points: usize,
) -> Option<f64> {
    cell_cost_lb_with(input, spec, gammas, &mut |lo, hi| {
        table.cut_moments(lo, hi, len_points)
    })
}

/// Lower-bound every cell of a sweep grid, in input order. `batched`
/// routes through the lane-parallel evaluator
/// ([`crate::queueing::simd::cells`]) when the `simd` feature is on: a
/// per-worker `CutMemo` dedupes the pure `cut_moments` calls neighboring
/// cells share, and the stability arithmetic runs up to `CELL_LANES`
/// cells in lockstep. Both arms are bit-identical — each lane performs
/// exactly the scalar [`cell_cost_lb`] operation sequence on its own
/// operands, and the memo returns the identical `CutMoments` a direct
/// call computes (property-tested in `tests/simd_dispatch.rs`).
/// One sweep cell: grid index, boundary combo, shared gamma, and the
/// index of the cell's per-tier SKU assignment row (always 0 on the
/// plain single-SKU grid).
pub(crate) type SweepCell<'a> = (usize, &'a [u32], f64, u32);

/// How a sweep cell's [`FleetSpec`] is built: the plain single-SKU grid
/// (`skus: None` — the verbatim pre-catalog builder, so plain sweeps are
/// untouched bit-for-bit) or a SKU catalog plus the enumerated per-tier
/// assignment rows a cell's fourth coordinate indexes into.
pub(crate) struct CellCtx<'a> {
    pub input: &'a PlanInput,
    pub skus: Option<(&'a SkuCatalog, &'a [Vec<usize>])>,
}

impl CellCtx<'_> {
    fn spec(&self, combo: &[u32], asg: u32) -> FleetSpec {
        match self.skus {
            None => self.input.gpu.fleet_spec(combo),
            Some((catalog, rows)) => {
                self.input
                    .gpu
                    .fleet_spec_skus(combo, catalog, &rows[asg as usize])
            }
        }
    }

    /// A mixed assignment can hand an upper tier no more KV slots than
    /// the last tier holds; such a spec violates the fleet's
    /// slot-monotonicity rule ([`FleetSpec::validate`]) and its cell is
    /// infeasible — the tier would buy nothing over the long tier. Plain
    /// cells (one SKU, slots inverse in window) satisfy it structurally.
    fn spec_feasible(&self, spec: &FleetSpec) -> bool {
        if self.skus.is_none() {
            return true;
        }
        let last = spec.tiers[spec.k() - 1].n_max;
        spec.tiers[..spec.k() - 1].iter().all(|t| t.n_max > last)
    }
}

fn cell_bounds(
    ctx: &CellCtx,
    cells: &[SweepCell],
    k: usize,
    table: &MomentTable,
    len_points: usize,
    batched: bool,
) -> Vec<Option<f64>> {
    #[cfg(feature = "simd")]
    if batched {
        return cell_bounds_batched(ctx, cells, k, table, len_points);
    }
    #[cfg(not(feature = "simd"))]
    let _ = batched;
    par_map_strided(cells, |&(_, combo, gamma, asg)| {
        let spec = ctx.spec(combo, asg);
        cell_cost_lb(ctx.input, &spec, &vec![gamma; k - 1], table, len_points)
    })
}

/// Worker-local state for the batched bound pass: the cut-moment memo
/// plus every buffer a block evaluation needs, recycled across blocks so
/// the steady-state pass performs no heap allocation — the scalar
/// per-cell path pays ~10 small allocations per cell.
#[cfg(feature = "simd")]
struct LbScratch {
    memo: crate::queueing::simd::cells::CutMemo,
    /// One recycled layout per lane.
    layouts: Vec<CellLayout>,
    /// Specs deduped by (boundary combo, SKU assignment) — the grid is
    /// combo-major, so a block usually spans one or two spec keys.
    specs: Vec<FleetSpec>,
    /// Per-cell gamma vector, refilled in place.
    gbuf: Vec<f64>,
    /// Flat `block.len() x k` stability counts.
    counts: Vec<u64>,
    /// Per-cell tier rates, refilled in place.
    rates: Vec<f64>,
}

#[cfg(feature = "simd")]
impl LbScratch {
    fn new() -> Self {
        Self {
            memo: crate::queueing::simd::cells::CutMemo::new(),
            layouts: Vec::new(),
            specs: Vec::new(),
            gbuf: Vec::new(),
            counts: Vec::new(),
            rates: Vec::new(),
        }
    }
}

/// The batched bound pass: cells are cut into `CELL_LANES`-cell blocks
/// and the blocks fan out strided across capped workers, each worker
/// owning its own [`LbScratch`]. The memo is deliberately worker-local —
/// a shared one would serialize every lookup on a lock, and striding
/// already lands neighboring blocks (which share most cuts) on the same
/// worker in rotation.
#[cfg(feature = "simd")]
fn cell_bounds_batched(
    ctx: &CellCtx,
    cells: &[SweepCell],
    k: usize,
    table: &MomentTable,
    len_points: usize,
) -> Vec<Option<f64>> {
    use crate::queueing::simd::cells::CELL_LANES;

    let blocks: Vec<&[SweepCell]> = cells.chunks(CELL_LANES).collect();
    let workers = crate::util::par::workers_for(blocks.len(), 2);
    let shards: Vec<Vec<Vec<Option<f64>>>> = if workers <= 1 {
        let mut scratch = LbScratch::new();
        vec![blocks
            .iter()
            .map(|b| lb_block(ctx, b, k, table, len_points, &mut scratch))
            .collect()]
    } else {
        let blocks_ref = &blocks;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    scope.spawn(move || {
                        let mut scratch = LbScratch::new();
                        blocks_ref
                            .iter()
                            .skip(w)
                            .step_by(workers)
                            .map(|b| lb_block(ctx, b, k, table, len_points, &mut scratch))
                            .collect::<Vec<Vec<Option<f64>>>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("bound worker panicked"))
                .collect()
        })
    };
    let mut iters: Vec<_> = shards.into_iter().map(|s| s.into_iter()).collect();
    let mut out = Vec::with_capacity(cells.len());
    for b in 0..blocks.len() {
        out.extend(iters[b % workers].next().expect("bound shard underflow"));
    }
    out
}

/// Lower-bound one block of up to `CELL_LANES` cells through the
/// lane-parallel stability evaluator. The per-tier lane fill replays
/// [`cell_cost_lb_with`]'s match arm exactly: a lane is live iff the tier
/// has a cut and traffic, an unboundable cut kills the whole cell (the
/// scalar `?` — later tiers of a dead cell skip the memo, as the scalar
/// early return does), and every other arm contributes a zero count.
/// Like the scalar bound, this is KV-blind and stays admissible under
/// [`PlanInput::kv`]: the KV floor only ever raises exact cell costs.
#[cfg(feature = "simd")]
fn lb_block(
    ctx: &CellCtx,
    block: &[SweepCell],
    k: usize,
    table: &MomentTable,
    len_points: usize,
    scratch: &mut LbScratch,
) -> Vec<Option<f64>> {
    use crate::queueing::simd::cells::{stability_counts_lanes, LaneInputs, CELL_LANES};

    let input = ctx.input;
    debug_assert!(block.len() <= CELL_LANES);
    scratch.specs.clear();
    while scratch.layouts.len() < block.len() {
        scratch.layouts.push(CellLayout::default());
    }
    let mut spec_of = [0usize; CELL_LANES];
    let mut last_key: Option<(&[u32], u32)> = None;
    for (j, &(_, combo, gamma, asg)) in block.iter().enumerate() {
        if last_key != Some((combo, asg)) {
            scratch.specs.push(ctx.spec(combo, asg));
            last_key = Some((combo, asg));
        }
        spec_of[j] = scratch.specs.len() - 1;
        scratch.gbuf.clear();
        scratch.gbuf.resize(k - 1, gamma);
        cell_layout_into(
            input,
            &scratch.specs[spec_of[j]],
            &scratch.gbuf,
            true,
            &mut scratch.layouts[j],
        );
    }
    let mut dead = [false; CELL_LANES];
    scratch.counts.clear();
    scratch.counts.resize(k * block.len(), 0);
    for t in 0..k {
        let mut li = LaneInputs::default();
        for (l, layout) in scratch.layouts[..block.len()].iter().enumerate() {
            if dead[l] {
                continue;
            }
            let (lambda_t, cut_t) = layout.tiers[t];
            match cut_t {
                Some((lo, hi)) if lambda_t > 0.0 => {
                    match scratch.memo.cut(table, lo, hi, len_points) {
                        Some(m) => {
                            let spec = &scratch.specs[spec_of[l]];
                            let n_slots = spec.tiers[t].n_max;
                            li.live[l] = true;
                            li.lambda[l] = lambda_t;
                            li.e_iter[l] = m.e_iter;
                            li.err_iter[l] = m.err_iter;
                            li.t_iter[l] = tier_t_iter_s(input, spec, t);
                            li.n_slots[l] = n_slots as f64;
                        }
                        None => dead[l] = true,
                    }
                }
                _ => {}
            }
        }
        let mut n_lb = [0u64; CELL_LANES];
        stability_counts_lanes(&li, input.cfg.rho_max, &mut n_lb);
        // N+k spares land on live lanes only — exactly the scalar bound's
        // `+ tier_redundancy` in its Some-with-traffic arm.
        let red_t = tier_redundancy(input, t);
        for (l, &n) in n_lb[..block.len()].iter().enumerate() {
            scratch.counts[l * k + t] = n + if li.live[l] { red_t } else { 0 };
        }
    }
    (0..block.len())
        .map(|l| {
            if dead[l] {
                return None;
            }
            scratch.rates.clear();
            let spec = &scratch.specs[spec_of[l]];
            scratch.rates.extend(spec.tiers.iter().map(|t| t.cost_hr));
            Some(fleet_cost_yr_tiered(
                &scratch.counts[l * k..(l + 1) * k],
                &scratch.rates,
            ))
        })
        .collect()
}

/// Every sweep cell's cost lower bound in grid order — the bound pass of
/// [`sweep_tiered_pruned`] exposed on its own for the batched-vs-scalar
/// identity property tests and the planner bench. `batched = true`
/// selects the lane-parallel evaluator when the `simd` feature is on (a
/// no-op fallback to scalar otherwise); both arms are bit-identical.
pub fn sweep_cell_bounds(input: &PlanInput, k: usize, batched: bool) -> Vec<Option<f64>> {
    assert!(k >= 2, "sweep_cell_bounds needs at least 2 tiers");
    let cands = candidate_boundaries(input);
    let combos = boundary_combos(&cands, k - 1);
    let mut cells: Vec<SweepCell> = Vec::with_capacity(combos.len() * input.cfg.gammas.len());
    for combo in &combos {
        for &gamma in &input.cfg.gammas {
            cells.push((cells.len(), combo.as_slice(), gamma, 0));
        }
    }
    let table = MomentTable::for_workload(&input.workload, input.gpu.chunk);
    let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
    let ctx = CellCtx { input, skus: None };
    cell_bounds(&ctx, &cells, k, &table, len_points, batched)
}

/// Bound-and-prune K-tier sweep: **the same argmin as [`sweep_tiered`],
/// bit-identical** (boundaries, gammas, per-tier GPU counts, cost —
/// property-tested on all three traces at K = 2, 3, 4), at a fraction of
/// the work. A cheap pass computes every cell's closed-form cost lower
/// bound from the shared [`MomentTable`]; cells whose bound exceeds an
/// exactly-evaluated incumbent by [`PRUNE_MARGIN`] are skipped — they can
/// neither win nor influence the grid-order tie-break (the margin dwarfs
/// the 1e-9 tie band). Surviving cells are evaluated through the verbatim
/// [`plan_tiers`] path against the shared [`CalibCache`], and the final
/// selection replays `sweep_tiered`'s sequential rule in grid order.
/// Returns no cost grid — use [`sweep_tiered`] when the full grid matters
/// (Table 8 reporting / the CLI sweep printout).
pub fn sweep_tiered_pruned(
    input: &PlanInput,
    k: usize,
    cache: &CalibCache,
) -> Result<(TieredPlan, PruneStats), SizingError> {
    sweep_tiered_pruned_seeded(input, k, cache, &[])
}

/// [`sweep_tiered_pruned`] with caller-provided incumbent seeds — cells
/// evaluated exactly *before* the pruning pass. The online
/// [`crate::planner::replan::Replanner`] seeds the neighbourhood of its
/// previous layout: under an unchanged workload fingerprint the optimum
/// rarely leaves it, so the incumbent is near-optimal immediately and the
/// bound prunes almost the whole grid. Seeds never change the result
/// (they only tighten the incumbent earlier).
pub fn sweep_tiered_pruned_seeded(
    input: &PlanInput,
    k: usize,
    cache: &CalibCache,
    seeds: &[(Vec<u32>, f64)],
) -> Result<(TieredPlan, PruneStats), SizingError> {
    assert!(k >= 2, "sweep_tiered_pruned needs at least 2 tiers");
    let cands = candidate_boundaries(input);
    let combos = boundary_combos(&cands, k - 1);
    if combos.is_empty() {
        return Err(SizingError::NoFeasibleTiering { k });
    }
    let mut cells: Vec<SweepCell> = Vec::with_capacity(combos.len() * input.cfg.gammas.len());
    for combo in &combos {
        for &gamma in &input.cfg.gammas {
            cells.push((cells.len(), combo.as_slice(), gamma, 0));
        }
    }
    let ctx = CellCtx { input, skus: None };
    sweep_pruned_cells(&ctx, k, &cells, cache, seeds)
}

/// Bound-and-prune over the SKU-generalized grid: every ascending
/// boundary combo crossed with the gamma grid crossed with every
/// per-tier SKU assignment over `catalog` ([`sku_assignments`] order —
/// the grid stays combo-major, then gamma, then assignment, so the
/// grid-order tie-break extends the plain sweep's). Assignments whose
/// spec breaks the fleet's slot-monotonicity rule are infeasible cells,
/// and the same closed-form bound prices each SKU's rate and cost before
/// any Erlang-C inversion. With the catalog-of-one
/// ([`SkuCatalog::single`]) the grid collapses onto the plain sweep's
/// and the selected plan matches [`sweep_tiered_pruned`] bit-for-bit on
/// everything but the recorded SKU choice (tested). This is the anytime
/// optimizer's small-space exhaustive oracle; the space grows as
/// `|catalog|^K`, which is exactly why [`crate::planner::anytime`]
/// exists for the rest.
pub fn sweep_tiered_skus_pruned(
    input: &PlanInput,
    k: usize,
    catalog: &SkuCatalog,
    cache: &CalibCache,
) -> Result<(TieredPlan, PruneStats), SizingError> {
    assert!(k >= 2, "sweep_tiered_skus_pruned needs at least 2 tiers");
    let cands = candidate_boundaries(input);
    let combos = boundary_combos(&cands, k - 1);
    if combos.is_empty() {
        return Err(SizingError::NoFeasibleTiering { k });
    }
    let rows = sku_assignments(catalog.len(), k);
    let mut cells: Vec<SweepCell> =
        Vec::with_capacity(combos.len() * input.cfg.gammas.len() * rows.len());
    for combo in &combos {
        for &gamma in &input.cfg.gammas {
            for a in 0..rows.len() as u32 {
                cells.push((cells.len(), combo.as_slice(), gamma, a));
            }
        }
    }
    let ctx = CellCtx {
        input,
        skus: Some((catalog, &rows)),
    };
    sweep_pruned_cells(&ctx, k, &cells, cache, &[])
}

/// The shared bound-and-prune engine behind [`sweep_tiered_pruned_seeded`]
/// and [`sweep_tiered_skus_pruned`]: bound every cell, seed an incumbent,
/// evaluate the survivors, replay the grid-order selection. On the plain
/// grid (`ctx.skus == None`, assignment column all zero) this body is the
/// pre-catalog sweep verbatim.
fn sweep_pruned_cells(
    ctx: &CellCtx,
    k: usize,
    cells: &[SweepCell],
    cache: &CalibCache,
    seeds: &[(Vec<u32>, f64)],
) -> Result<(TieredPlan, PruneStats), SizingError> {
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    let input = ctx.input;
    let table = MomentTable::for_workload(&input.workload, input.gpu.chunk);
    let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
    let batched = crate::util::simd::simd_active();
    let lbs: Vec<Option<f64>> = cell_bounds(ctx, cells, k, &table, len_points, batched);

    let eval = |combo: &[u32], gamma: f64, asg: u32| -> Result<TieredPlan, SizingError> {
        let spec = ctx.spec(combo, asg);
        if !ctx.spec_feasible(&spec) {
            return Err(SizingError::NoFeasibleTiering { k });
        }
        plan_tiers(input, &spec, &vec![gamma; k - 1], true, Some(cache))
    };

    // Incumbent: caller seeds plus cheapest-lower-bound cells until one
    // evaluates feasibly. Exact costs only — the prune proof needs the
    // incumbent to be an achieved cell cost, never a bound. Positive f64
    // bit patterns order like the values, so an atomic u64 min suffices.
    // Seed results are kept by cell index so the main pass reuses them
    // instead of re-running the sizing inversions.
    let best_bits = AtomicU64::new(f64::INFINITY.to_bits());
    let mut seed_plans: Vec<Option<TieredPlan>> = vec![None; cells.len()];
    let mut seeded = 0usize;
    let mut seed_cell = |i: usize, seeded: &mut usize| -> bool {
        if seed_plans[i].is_some() {
            return true;
        }
        let (_, combo, gamma, asg) = cells[i];
        if let Ok(p) = eval(combo, gamma, asg) {
            best_bits.fetch_min(p.cost_yr.to_bits(), Ordering::Relaxed);
            seed_plans[i] = Some(p);
            *seeded += 1;
            return true;
        }
        false
    };
    for (combo, gamma) in seeds {
        // Only grid cells may seed: an off-grid incumbent cheaper than
        // every grid cell would let the bound prune the real winner (and
        // a wrong-arity combo would not even size). Foreign seeds are
        // ignored, which is merely slower.
        let idx = cells
            .iter()
            .find(|&&(_, c, g, _)| c == combo.as_slice() && g.to_bits() == gamma.to_bits());
        if let Some(&(i, _, _, _)) = idx {
            seed_cell(i, &mut seeded);
        }
    }
    let mut by_lb: Vec<usize> = (0..cells.len()).filter(|&i| lbs[i].is_some()).collect();
    by_lb.sort_by(|&a, &b| lbs[a].partial_cmp(&lbs[b]).expect("finite bounds"));
    for &i in by_lb.iter().take(8) {
        if seed_cell(i, &mut seeded) {
            break;
        }
    }

    // Strided fan-out: surviving cells cluster around the optimum in grid
    // order, and contiguous sharding would hand the whole expensive
    // cluster to one worker. Which cells get pruned varies with the
    // worker schedule through the incumbent atomic; the prune-margin
    // proof guarantees the *selected plan* cannot.
    let pruned_n = AtomicUsize::new(0);
    let infeasible_n = AtomicUsize::new(0);
    let plans: Vec<Option<TieredPlan>> = par_map_strided(cells, |&(i, combo, gamma, asg)| {
        if let Some(p) = &seed_plans[i] {
            return Some(p.clone());
        }
        if let Some(lb) = lbs[i] {
            let incumbent = f64::from_bits(best_bits.load(Ordering::Relaxed));
            if lb >= incumbent + PRUNE_MARGIN {
                pruned_n.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        }
        match eval(combo, gamma, asg) {
            Ok(p) => {
                best_bits.fetch_min(p.cost_yr.to_bits(), Ordering::Relaxed);
                Some(p)
            }
            Err(_) => {
                infeasible_n.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    });

    // Verbatim `sweep_tiered` selection over the evaluated cells in grid
    // order: first strictly-better (> 1e-9) wins, ties break earliest.
    let mut best: Option<TieredPlan> = None;
    let mut evaluated = 0usize;
    for plan in plans.into_iter().flatten() {
        evaluated += 1;
        let better = match &best {
            None => true,
            Some(b) => plan.cost_yr < b.cost_yr - 1e-9,
        };
        if better {
            best = Some(plan);
        }
    }
    let stats = PruneStats {
        cells: cells.len(),
        pruned: pruned_n.load(Ordering::Relaxed),
        evaluated,
        infeasible: infeasible_n.load(Ordering::Relaxed),
        seeded,
    };
    let best = best.ok_or(SizingError::NoFeasibleTiering { k })?;
    Ok((best, stats))
}

/// The sweep-grid neighbourhood of an adopted layout: the layout's own
/// boundary combo crossed with the full gamma grid, plus every one-grid-
/// step single-boundary perturbation at the nearest grid gamma. The
/// replanner evaluates these as incumbent seeds on unchanged-fingerprint
/// epochs (see [`sweep_tiered_pruned_seeded`]). Empty when the layout's
/// boundaries are no longer inside the candidate grid (drift changed the
/// CDF support) — the sweep then runs unseeded, which is merely slower.
pub fn layout_neighborhood(input: &PlanInput, plan: &TieredPlan) -> Vec<(Vec<u32>, f64)> {
    let cands = candidate_boundaries(input);
    let bounds = plan.boundaries();
    let pos: Option<Vec<usize>> = bounds
        .iter()
        .map(|b| cands.iter().position(|c| c == b))
        .collect();
    let Some(pos) = pos else {
        return Vec::new();
    };
    let mut seeds: Vec<(Vec<u32>, f64)> = Vec::new();
    for &g in &input.cfg.gammas {
        seeds.push((bounds.clone(), g));
    }
    let g0 = plan.gammas.first().copied().unwrap_or(1.0);
    let nearest = input
        .cfg
        .gammas
        .iter()
        .copied()
        .min_by(|a, b| {
            (a - g0)
                .abs()
                .partial_cmp(&(b - g0).abs())
                .expect("finite gammas")
        })
        .unwrap_or(1.0);
    for (j, &p) in pos.iter().enumerate() {
        for np in [p.wrapping_sub(1), p + 1] {
            if np >= cands.len() {
                continue;
            }
            let mut nb = bounds.clone();
            nb[j] = cands[np];
            let ascending = nb.windows(2).all(|w| w[1] > w[0]);
            if ascending && !seeds.iter().any(|(s, g)| s == &nb && *g == nearest) {
                seeds.push((nb, nearest));
            }
        }
    }
    seeds
}

/// Plan a fleet at a fixed [`FleetSpec`], sweeping the shared gamma grid
/// and keeping the cheapest plan (ties break toward smaller gamma, as in
/// Algorithm 1). Used by the `--tiers W1,W2,..` CLI path and the
/// config-file examples.
pub fn plan_spec_sweep_gamma(
    input: &PlanInput,
    spec: &FleetSpec,
) -> Result<TieredPlan, SizingError> {
    plan_spec_sweep_gamma_cached(input, spec, &CalibCache::new())
}

/// [`plan_spec_sweep_gamma`] against a caller-owned calibration cache (the
/// replanner's per-epoch gamma re-sweep; bit-identical results).
pub fn plan_spec_sweep_gamma_cached(
    input: &PlanInput,
    spec: &FleetSpec,
    cache: &CalibCache,
) -> Result<TieredPlan, SizingError> {
    let k = spec.k();
    let mut best: Option<TieredPlan> = None;
    for &gamma in &input.cfg.gammas {
        // Infeasible grid cells are skipped, exactly as in sweep_tiered:
        // one gamma blowing the SLO must not abort the whole sweep.
        let Ok(plan) = plan_tiers(input, spec, &vec![gamma; k - 1], true, Some(cache)) else {
            continue;
        };
        let better = match &best {
            None => true,
            Some(b) => plan.cost_yr < b.cost_yr - 1e-9,
        };
        if better {
            best = Some(plan);
        }
    }
    best.ok_or(SizingError::NoFeasibleTiering { k })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::sweep::{plan_fleet, sweep_full};
    use crate::workload::traces;

    fn azure_input() -> PlanInput {
        let mut i = PlanInput::new(traces::azure(), 1000.0);
        i.cfg.mc_samples = 8_000;
        i
    }

    #[test]
    fn k2_projection_is_bit_identical_to_plan_fleet() {
        let input = azure_input();
        for gamma in [1.0, 1.5, 2.0] {
            let spec = input.gpu.fleet_spec(&[4096]);
            let tp = plan_tiers(&input, &spec, &[gamma], true, None).unwrap();
            assert_eq!(tp.k(), 2);
            let p2 = tp.into_two_pool();
            let p = plan_fleet(&input, 4096, gamma).unwrap();
            assert_eq!(p2.short.n_gpus, p.short.n_gpus);
            assert_eq!(p2.long.n_gpus, p.long.n_gpus);
            assert_eq!(p2.short.lambda.to_bits(), p.short.lambda.to_bits());
            assert_eq!(p2.long.lambda.to_bits(), p.long.lambda.to_bits());
            assert_eq!(p2.cost_yr.to_bits(), p.cost_yr.to_bits());
            assert_eq!(p2.alpha_prime.to_bits(), p.alpha_prime.to_bits());
        }
    }

    #[test]
    fn k3_traffic_is_conserved() {
        let input = azure_input();
        let spec = input.gpu.fleet_spec(&[2048, 8192]);
        let tp = plan_tiers(&input, &spec, &[1.5, 1.5], true, None).unwrap();
        assert_eq!(tp.k(), 3);
        let total: f64 = tp.tiers.iter().map(|t| t.lambda).sum();
        assert!((total - 1000.0).abs() < 1e-9, "total lambda {total}");
        for t in &tp.tiers {
            assert!(t.lambda >= 0.0);
        }
    }

    #[test]
    fn band_is_clamped_at_next_boundary() {
        let input = azure_input();
        let spec = input.gpu.fleet_spec(&[1024, 1536]);
        let tp = plan_tiers(&input, &spec, &[2.0, 2.0], true, None).unwrap();
        assert!((tp.gammas[0] - 1.5).abs() < 1e-12, "gamma0 {}", tp.gammas[0]);
        assert!((tp.gammas[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn k3_never_loses_to_k2_on_azure_sweep() {
        let input = azure_input();
        let (best2, _) = sweep_full(&input).unwrap();
        let (best3, grid3) = sweep_tiered(&input, 3).unwrap();
        assert!(!grid3.is_empty());
        // Integer sizing can cost a GPU or two at the margin, but a third
        // tier must never be materially worse than the two-pool optimum.
        assert!(
            best3.cost_yr <= best2.cost_yr * 1.05,
            "K=3 {} vs K=2 {}",
            best3.cost_yr,
            best2.cost_yr
        );
    }

    #[test]
    fn tiered_sweep_parallel_matches_serial() {
        let input = azure_input();
        let (bp, gp) = sweep_tiered(&input, 3).unwrap();
        let (bs, gs) = sweep_tiered_serial(&input, 3).unwrap();
        assert_eq!(gp, gs);
        assert_eq!(bp.cost_yr.to_bits(), bs.cost_yr.to_bits());
        assert_eq!(bp.boundaries(), bs.boundaries());
        assert_eq!(bp.gpu_counts(), bs.gpu_counts());
    }

    #[test]
    fn per_tier_slo_equal_to_global_is_bit_identical() {
        // Spelling the fleet default out per tier must not change a single
        // bit of the plan (the satellite acceptance gate for per-tier SLOs).
        let input = azure_input();
        let spec = input.gpu.fleet_spec(&[2048, 8192]);
        let base = plan_tiers(&input, &spec, &[1.5, 1.5], true, None).unwrap();
        let mut explicit = spec.clone();
        for t in &mut explicit.tiers {
            t.p99_ttft_s = Some(input.slo.p99_ttft_s);
        }
        let same = plan_tiers(&input, &explicit, &[1.5, 1.5], true, None).unwrap();
        assert_eq!(base.gpu_counts(), same.gpu_counts());
        assert_eq!(base.cost_yr.to_bits(), same.cost_yr.to_bits());
        for (a, b) in base.tiers.iter().zip(&same.tiers) {
            assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
        }
    }

    #[test]
    fn tighter_tier_slo_needs_no_fewer_gpus() {
        let input = azure_input();
        let spec = input.gpu.fleet_spec(&[4096]);
        let base = plan_tiers(&input, &spec, &[1.5], true, None).unwrap();
        let mut tight = spec.clone();
        tight.tiers[1].p99_ttft_s = Some(0.05); // 10x tighter than 0.5 s
        let plan = plan_tiers(&input, &tight, &[1.5], true, None).unwrap();
        assert!(plan.tiers[1].n_gpus >= base.tiers[1].n_gpus);
        // The untouched tier keeps its sizing bit-for-bit.
        assert_eq!(plan.tiers[0].n_gpus, base.tiers[0].n_gpus);
    }

    #[test]
    fn cached_sweeps_match_fresh_sweeps() {
        let input = azure_input();
        let cache = CalibCache::new();
        let (a, ga) = sweep_tiered(&input, 3).unwrap();
        let (b, gb) = sweep_tiered_cached(&input, 3, &cache).unwrap();
        assert_eq!(ga, gb);
        assert_eq!(a.cost_yr.to_bits(), b.cost_yr.to_bits());
        assert!(!cache.is_empty(), "warm-start cache must be populated");
        // Re-running against the warm cache is still bit-identical.
        let (c, gc) = sweep_tiered_cached(&input, 3, &cache).unwrap();
        assert_eq!(ga, gc);
        assert_eq!(a.gpu_counts(), c.gpu_counts());
    }

    #[test]
    fn pruned_sweep_matches_full_sweep_bitwise() {
        // The acceptance identity (also covered across all traces and
        // K = 2..4 in `tests/planner_fastpath.rs`): bound-and-prune must
        // select the exact cell, counts and cost of the full sweep.
        let input = azure_input();
        for k in [2usize, 3] {
            let (full, _) = sweep_tiered(&input, k).unwrap();
            let (fast, stats) = sweep_tiered_pruned(&input, k, &CalibCache::new()).unwrap();
            assert_eq!(fast.cost_yr.to_bits(), full.cost_yr.to_bits(), "K={k}");
            assert_eq!(fast.boundaries(), full.boundaries(), "K={k}");
            assert_eq!(fast.gpu_counts(), full.gpu_counts(), "K={k}");
            for (a, b) in fast.gammas.iter().zip(&full.gammas) {
                assert_eq!(a.to_bits(), b.to_bits(), "K={k}");
            }
            assert_eq!(stats.cells, stats.pruned + stats.evaluated + stats.infeasible);
            assert!(stats.pruned > 0, "K={k}: bound never fired");
        }
    }

    #[test]
    fn cost_lower_bound_never_exceeds_exact_cost() {
        // Soundness of the prune bound on a spread of evaluated cells.
        let input = azure_input();
        let table =
            crate::queueing::service::MomentTable::for_workload(&input.workload, input.gpu.chunk);
        let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
        for b in [1024u32, 2048, 4096, 8192] {
            for gamma in [1.0, 1.4, 2.0] {
                let spec = input.gpu.fleet_spec(&[b]);
                let Ok(plan) = plan_tiers(&input, &spec, &[gamma], true, None) else {
                    continue;
                };
                let lb = cell_cost_lb(&input, &spec, &[gamma], &table, len_points)
                    .expect("boundable cell");
                assert!(
                    lb <= plan.cost_yr + 1e-6,
                    "B={b} gamma={gamma}: lb {lb} > cost {}",
                    plan.cost_yr
                );
            }
        }
    }

    #[test]
    fn batched_cell_bounds_match_scalar_bitwise() {
        // The K4 acceptance identity at its source: the lane-parallel
        // memoized bound pass must reproduce every scalar bound exactly
        // (full trace coverage lives in `tests/simd_dispatch.rs`).
        let input = azure_input();
        for k in [2usize, 3] {
            let scalar = sweep_cell_bounds(&input, k, false);
            let batched = sweep_cell_bounds(&input, k, true);
            assert_eq!(scalar.len(), batched.len(), "K={k}");
            for (i, (s, b)) in scalar.iter().zip(&batched).enumerate() {
                match (s, b) {
                    (Some(x), Some(y)) => {
                        assert_eq!(x.to_bits(), y.to_bits(), "cell {i} K={k}");
                    }
                    (None, None) => {}
                    _ => panic!("cell {i} K={k}: bound presence differs"),
                }
            }
        }
    }

    #[test]
    fn seeded_pruned_sweep_is_seed_invariant() {
        let input = azure_input();
        let cache = CalibCache::new();
        let (plain, _) = sweep_tiered_pruned(&input, 3, &cache).unwrap();
        let seeds = layout_neighborhood(&input, &plain);
        assert!(!seeds.is_empty());
        let (seeded, stats) = sweep_tiered_pruned_seeded(&input, 3, &cache, &seeds).unwrap();
        assert_eq!(seeded.cost_yr.to_bits(), plain.cost_yr.to_bits());
        assert_eq!(seeded.boundaries(), plain.boundaries());
        assert_eq!(seeded.gpu_counts(), plain.gpu_counts());
        assert!(stats.seeded > seeds.len() / 2, "seeds must actually evaluate");
    }

    #[test]
    fn combos_enumerate_in_lexicographic_order() {
        let c = boundary_combos(&[1, 2, 3, 4], 2);
        assert_eq!(
            c,
            vec![
                vec![1, 2],
                vec![1, 3],
                vec![1, 4],
                vec![2, 3],
                vec![2, 4],
                vec![3, 4]
            ]
        );
        assert_eq!(boundary_combos(&[1, 2], 3), Vec::<Vec<u32>>::new());
        assert_eq!(boundary_combos(&[1, 2], 1), vec![vec![1], vec![2]]);
    }

    #[test]
    fn sku_assignments_enumerate_odometer_order() {
        assert_eq!(sku_assignments(1, 3), vec![vec![0, 0, 0]]);
        assert_eq!(
            sku_assignments(2, 2),
            vec![vec![0, 0], vec![0, 1], vec![1, 0], vec![1, 1]]
        );
        let rows = sku_assignments(3, 3);
        assert_eq!(rows.len(), 27);
        assert_eq!(rows[0], vec![0, 0, 0]);
        assert_eq!(rows[26], vec![2, 2, 2]);
        // Strictly lexicographic: each row sorts after its predecessor.
        assert!(rows.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn catalog_of_one_sku_sweep_matches_plain_sweep_bitwise() {
        // The tentpole's bit-identity pin: on the single-SKU projection
        // the generalized sweep must select the exact plain-sweep cell —
        // same boundaries, counts, gammas and cost to the bit.
        let input = azure_input();
        let catalog = crate::config::SkuCatalog::single(&input.gpu);
        for k in [2usize, 3] {
            let (plain, _) = sweep_tiered_pruned(&input, k, &CalibCache::new()).unwrap();
            let (skus, stats) =
                sweep_tiered_skus_pruned(&input, k, &catalog, &CalibCache::new()).unwrap();
            assert_eq!(skus.cost_yr.to_bits(), plain.cost_yr.to_bits(), "K={k}");
            assert_eq!(skus.boundaries(), plain.boundaries(), "K={k}");
            assert_eq!(skus.gpu_counts(), plain.gpu_counts(), "K={k}");
            for (a, b) in skus.gammas.iter().zip(&plain.gammas) {
                assert_eq!(a.to_bits(), b.to_bits(), "K={k}");
            }
            // Same grid, one assignment row each — and every tier records
            // the catalog-of-one choice.
            assert_eq!(stats.cells, plain_grid_cells(&input, k), "K={k}");
            assert!(skus.spec.tiers.iter().all(|t| t.sku_index() == Some(0)));
        }
    }

    fn plain_grid_cells(input: &PlanInput, k: usize) -> usize {
        let cands = candidate_boundaries(input);
        boundary_combos(&cands, k - 1).len() * input.cfg.gammas.len()
    }

    #[test]
    fn mixed_sku_sweep_never_loses_to_single_sku() {
        // The demo catalog contains the base SKU, so the uniform-base
        // assignment is in the mixed grid: its optimum can only improve
        // on the plain sweep's.
        let input = azure_input();
        let catalog = crate::config::SkuCatalog::demo(&input.gpu);
        let (plain, _) = sweep_tiered_pruned(&input, 2, &CalibCache::new()).unwrap();
        let (mixed, stats) =
            sweep_tiered_skus_pruned(&input, 2, &catalog, &CalibCache::new()).unwrap();
        assert!(
            mixed.cost_yr <= plain.cost_yr + 1e-9,
            "mixed {} vs single {}",
            mixed.cost_yr,
            plain.cost_yr
        );
        assert_eq!(stats.cells, plain_grid_cells(&input, 2) * 9);
        assert_eq!(stats.cells, stats.pruned + stats.evaluated + stats.infeasible);
        // Traffic conservation still holds under a mixed assignment.
        let total: f64 = mixed.tiers.iter().map(|t| t.lambda).sum();
        assert!((total - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn sku_cost_lower_bound_never_exceeds_exact_cost() {
        // Prune-bound soundness on mu-scaled, re-slotted SKU specs — the
        // mixed-grid analog of `cost_lower_bound_never_exceeds_exact_cost`.
        let input = azure_input();
        let catalog = crate::config::SkuCatalog::demo(&input.gpu);
        let table =
            crate::queueing::service::MomentTable::for_workload(&input.workload, input.gpu.chunk);
        let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
        let mut checked = 0usize;
        for b in [2048u32, 4096, 8192] {
            for asg in sku_assignments(catalog.len(), 2) {
                for gamma in [1.0, 1.4] {
                    let spec = input.gpu.fleet_spec_skus(&[b], &catalog, &asg);
                    let Ok(plan) = plan_tiers(&input, &spec, &[gamma], true, None) else {
                        continue;
                    };
                    let lb = cell_cost_lb(&input, &spec, &[gamma], &table, len_points)
                        .expect("boundable cell");
                    assert!(
                        lb <= plan.cost_yr + 1e-6,
                        "B={b} asg={asg:?} gamma={gamma}: lb {lb} > cost {}",
                        plan.cost_yr
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked >= 12, "too few feasible SKU cells: {checked}");
    }

    #[test]
    fn mu_scaled_tier_sizes_like_a_faster_gpu() {
        // A uniformly faster SKU (same slots, higher mu) can never need
        // more GPUs in any tier, and its t_iter bound input shrinks.
        let input = azure_input();
        let mut catalog = crate::config::SkuCatalog::single(&input.gpu);
        catalog.skus[0].mu_scale = 2.0;
        let spec = input.gpu.fleet_spec(&[4096]);
        let fast = input.gpu.fleet_spec_skus(&[4096], &catalog, &[0, 0]);
        let base_plan = plan_tiers(&input, &spec, &[1.5], true, None).unwrap();
        let fast_plan = plan_tiers(&input, &fast, &[1.5], true, None).unwrap();
        for (b, f) in base_plan.tiers.iter().zip(&fast_plan.tiers) {
            assert!(f.n_gpus <= b.n_gpus, "fast {} vs base {}", f.n_gpus, b.n_gpus);
            // Identical traffic split: mu scaling touches service only.
            assert_eq!(b.lambda.to_bits(), f.lambda.to_bits());
        }
        assert_eq!(tier_t_iter_s(&input, &spec, 0).to_bits(), {
            let t = input.gpu.t_iter_s(spec.tiers[0].n_max);
            t.to_bits()
        });
        assert_eq!(
            tier_t_iter_s(&input, &fast, 0).to_bits(),
            (input.gpu.t_iter_s(fast.tiers[0].n_max) / 2.0).to_bits()
        );
    }
}
