//! Deadline-bounded anytime search over the SKU-generalized planning
//! space — the planner's first search subsystem beyond exhaustive sweeps.
//!
//! With a heterogeneous catalog a cell is (boundary combo × gamma ×
//! per-tier SKU assignment) and the grid grows as `|catalog|^K`; the
//! exact bound-and-prune sweep ([`sweep_tiered_skus_pruned`]) stops being
//! reachable. [`anytime_search`] keeps the exact sweep as the small-space
//! oracle and otherwise runs two phases under a [`Deadline`] terminator:
//!
//! 1. **Budgeted exploration** — a deterministic seeded sample of SKU
//!    assignments and boundary jitter around the plain-sweep argmin,
//!    evaluated in closed-form lower-bound order (the frontier), so the
//!    cheapest-looking cells spend the budget first.
//! 2. **Compression toward the incumbent** — coordinate descent over one
//!    tier's SKU, one boundary, or the gamma at a time, first-improvement
//!    (`> 1e-9`), until a round passes with no move or the deadline
//!    fires.
//!
//! Determinism: the candidate sequence is a pure function of the seed —
//! the deadline only *truncates* it, it never reorders it — so an
//! unbounded run is bit-reproducible across machines and thread counts
//! (batch evaluation preserves input order), and a bounded run returns a
//! prefix-incumbent of the same sequence.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::config::SkuCatalog;
use crate::planner::sizing::SizingError;
use crate::planner::sweep::{candidate_boundaries, CalibCache, PlanInput};
use crate::planner::tiered::{
    boundary_combos, cell_cost_lb, plan_tiers, sku_sweep_space, sweep_tiered_pruned,
    sweep_tiered_skus_pruned, TieredPlan,
};
use crate::queueing::service::MomentTable;
use crate::util::par::par_map_strided;
use crate::util::rng::Rng;

/// A wall-clock terminator. [`Deadline::none`] never fires, so the
/// evaluated-cell sequence of an unbounded search has no wall-clock
/// dependence at all — the determinism tests rest on this.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    start: Instant,
    budget: Option<Duration>,
}

impl Deadline {
    /// Never expires.
    pub fn none() -> Deadline {
        Deadline {
            start: Instant::now(),
            budget: None,
        }
    }

    /// Expires `ms` milliseconds after this call.
    pub fn after_ms(ms: u64) -> Deadline {
        Deadline {
            start: Instant::now(),
            budget: Some(Duration::from_millis(ms)),
        }
    }

    pub fn expired(&self) -> bool {
        match self.budget {
            None => false,
            Some(b) => self.start.elapsed() >= b,
        }
    }

    pub fn is_bounded(&self) -> bool {
        self.budget.is_some()
    }
}

/// Tuning knobs for [`anytime_search`]. The defaults fit the 50 ms CI
/// budget on a warm [`CalibCache`]; callers with more wall-clock raise
/// `explore_cells` (the deadline still dominates when set).
#[derive(Clone, Debug)]
pub struct AnytimeConfig {
    /// Seed of the deterministic candidate sequence.
    pub seed: u64,
    /// Exact evaluations the exploration phase may spend (deadline
    /// permitting). Four candidates are sampled per budgeted evaluation,
    /// so the lower-bound ordering has a real frontier to choose from.
    pub explore_cells: usize,
    /// Coordinate-descent rounds over the incumbent (early-stopped on
    /// the first round with no improving move).
    pub compress_rounds: usize,
    /// Largest SKU-generalized grid the search hands to the exhaustive
    /// [`sweep_tiered_skus_pruned`] oracle instead of sampling (only
    /// when no deadline is set — the oracle cannot be truncated).
    pub exhaustive_cells: usize,
}

impl Default for AnytimeConfig {
    fn default() -> Self {
        AnytimeConfig {
            seed: 42,
            explore_cells: 128,
            compress_rounds: 8,
            exhaustive_cells: 4096,
        }
    }
}

/// What [`anytime_search`] found and how hard it looked.
#[derive(Clone, Debug)]
pub struct AnytimeResult {
    /// The incumbent: best plan found before the deadline.
    pub plan: TieredPlan,
    /// Exact cell evaluations performed (quadrature + Erlang inversion),
    /// including the baseline sweep's.
    pub cells_evaluated: usize,
    /// Frontier-relative optimality gap, percent: how far the cheapest
    /// *sampled but never evaluated* cell's lower bound sits below the
    /// incumbent (0 when the frontier was exhausted or the search was
    /// exact). A sampling gap, not a global certificate — cells outside
    /// the sample are not bounded.
    pub bound_gap_pct: f64,
    /// True when the result is the exact grid argmin (oracle paths).
    pub exact: bool,
}

fn exact_result(plan: TieredPlan, cells_evaluated: usize) -> AnytimeResult {
    AnytimeResult {
        plan,
        cells_evaluated,
        bound_gap_pct: 0.0,
        exact: true,
    }
}

/// Anytime SKU-aware planning. Dispatch:
///
/// * `catalog: None` — the plain single-SKU grid *is* small enough:
///   delegate to [`sweep_tiered_pruned`] and return its argmin
///   bit-identically (the acceptance pin).
/// * catalog of one, or a mixed space within `exhaustive_cells` and no
///   deadline — delegate to the exact [`sweep_tiered_skus_pruned`].
/// * otherwise — seeded sampling plus compression (module docs).
///
/// Phase 0 of the sampled path always runs: the plain-sweep argmin plus
/// every SKU's uniform assignment at that cell, so whenever the catalog
/// contains the base SKU the incumbent starts at-or-below the single-SKU
/// optimum — the mixed-vs-single guarantee Table 10 reports.
pub fn anytime_search(
    input: &PlanInput,
    k: usize,
    catalog: Option<&SkuCatalog>,
    cache: &CalibCache,
    deadline: Deadline,
    cfg: &AnytimeConfig,
) -> Result<AnytimeResult, SizingError> {
    assert!(k >= 2, "anytime_search needs at least 2 tiers");
    let Some(catalog) = catalog else {
        let (plan, stats) = sweep_tiered_pruned(input, k, cache)?;
        return Ok(exact_result(plan, stats.evaluated));
    };
    assert!(!catalog.is_empty(), "anytime_search needs a non-empty catalog");
    let space = sku_sweep_space(input, k, catalog);
    if catalog.len() == 1 || (space <= cfg.exhaustive_cells && !deadline.is_bounded()) {
        let (plan, stats) = sweep_tiered_skus_pruned(input, k, catalog, cache)?;
        return Ok(exact_result(plan, stats.evaluated));
    }
    sampled_search(input, k, catalog, cache, deadline, cfg)
}

fn improves(new_cost: f64, cur: Option<f64>) -> bool {
    match cur {
        None => true,
        Some(c) => new_cost < c - 1e-9,
    }
}

/// Index of the grid gamma nearest to `g0` (first wins ties) — the same
/// re-gridding rule [`crate::planner::tiered::layout_neighborhood`] uses
/// to map a plan's clamped effective gamma back onto the sweep grid.
fn nearest_gamma_idx(gammas: &[f64], g0: f64) -> usize {
    gammas
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            (*a - g0)
                .abs()
                .partial_cmp(&(*b - g0).abs())
                .expect("finite gammas")
        })
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// One sampled cell: boundary combo, gamma grid index, SKU assignment.
type Cand = (Vec<u32>, usize, Vec<usize>);

fn sampled_search(
    input: &PlanInput,
    k: usize,
    catalog: &SkuCatalog,
    cache: &CalibCache,
    deadline: Deadline,
    cfg: &AnytimeConfig,
) -> Result<AnytimeResult, SizingError> {
    let s = catalog.len();
    let cands = candidate_boundaries(input);
    let combos = boundary_combos(&cands, k - 1);
    if combos.is_empty() {
        return Err(SizingError::NoFeasibleTiering { k });
    }
    let gammas = &input.cfg.gammas;
    let evals = AtomicUsize::new(0);

    // One exact cell evaluation. Slot-monotonicity failures (an upper
    // tier holding no more KV slots than the last) are infeasible cells,
    // exactly as in the exhaustive SKU sweep.
    let eval = |combo: &[u32], gamma: f64, asg: &[usize]| -> Option<TieredPlan> {
        evals.fetch_add(1, Ordering::Relaxed);
        let spec = input.gpu.fleet_spec_skus(combo, catalog, asg);
        let last = spec.tiers[k - 1].n_max;
        if spec.tiers[..k - 1].iter().any(|t| t.n_max <= last) {
            return None;
        }
        plan_tiers(input, &spec, &vec![gamma; k - 1], true, Some(cache)).ok()
    };

    // Phase 0 — baseline: the plain single-SKU argmin anchors both the
    // incumbent (every SKU's uniform assignment at that cell) and the
    // jitter neighbourhood below. A plain-infeasible input degrades to
    // pure uniform sampling.
    let plain = sweep_tiered_pruned(input, k, cache).ok();
    let plain_evals = plain.as_ref().map_or(0, |(_, st)| st.evaluated);
    let baseline: Option<(Vec<usize>, usize)> = plain.as_ref().and_then(|(p, _)| {
        let pos: Option<Vec<usize>> = p
            .boundaries()
            .iter()
            .map(|b| cands.iter().position(|c| c == b))
            .collect();
        let gi = nearest_gamma_idx(gammas, p.gammas.first().copied().unwrap_or(1.0));
        pos.map(|pos| (pos, gi))
    });

    let mut incumbent: Option<(Cand, TieredPlan)> = None;
    if let Some((pos, gi)) = &baseline {
        let combo: Vec<u32> = pos.iter().map(|&p| cands[p]).collect();
        for sku in 0..s {
            let asg = vec![sku; k];
            if let Some(p) = eval(&combo, gammas[*gi], &asg) {
                if improves(p.cost_yr, incumbent.as_ref().map(|(_, b)| b.cost_yr)) {
                    incumbent = Some(((combo.clone(), *gi, asg), p));
                }
            }
        }
    }

    // Exploration candidates: half jittered ±2 grid steps around the
    // baseline, half uniform over the grid; gamma and per-tier SKUs
    // uniform. Pure function of the seed.
    let mut rng = Rng::new(cfg.seed);
    // Four candidates per budgeted evaluation, capped so an effectively
    // unbounded budget cannot allocate an unbounded sample.
    let n_samples = cfg.explore_cells.saturating_mul(4).clamp(s.min(16_384), 16_384);
    let mut cand_cells: Vec<Cand> = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let combo: Vec<u32> = match &baseline {
            Some((pos, _)) if rng.bool(0.5) => {
                let mut jp = pos.clone();
                for p in jp.iter_mut() {
                    let d = rng.range(0, 5) as i64 - 2;
                    *p = (*p as i64 + d).clamp(0, cands.len() as i64 - 1) as usize;
                }
                if jp.windows(2).all(|w| w[1] > w[0]) {
                    jp.iter().map(|&p| cands[p]).collect()
                } else {
                    // Jitter collided two boundaries; this draw falls
                    // back to a uniform combo (still deterministic).
                    combos[rng.range(0, combos.len())].clone()
                }
            }
            _ => combos[rng.range(0, combos.len())].clone(),
        };
        let gi = rng.range(0, gammas.len());
        let asg: Vec<usize> = (0..k).map(|_| rng.range(0, s)).collect();
        cand_cells.push((combo, gi, asg));
    }

    // Lower-bound the sample and order the frontier cheapest-first
    // (stable: ties and unboundable cells keep sample order).
    let table = MomentTable::for_workload(&input.workload, input.gpu.chunk);
    let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
    let lbs: Vec<Option<f64>> = par_map_strided(&cand_cells, |c| {
        let (combo, gi, asg) = c;
        let spec = input.gpu.fleet_spec_skus(combo, catalog, asg);
        cell_cost_lb(input, &spec, &vec![gammas[*gi]; k - 1], &table, len_points)
    });
    let mut order: Vec<usize> = (0..cand_cells.len()).collect();
    order.sort_by(|&a, &b| match (lbs[a], lbs[b]) {
        (Some(x), Some(y)) => x.partial_cmp(&y).expect("finite bounds").then(a.cmp(&b)),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => a.cmp(&b),
    });

    // Budgeted exploration in small order-preserving batches; the
    // deadline is checked between batches and only ever truncates.
    const BATCH: usize = 8;
    let mut explored = 0usize;
    let mut next = 0usize;
    while next < order.len() && explored < cfg.explore_cells && !deadline.expired() {
        let end = (next + BATCH).min(order.len());
        let batch = &order[next..end];
        let results: Vec<Option<TieredPlan>> = par_map_strided(batch, |&i| {
            let (combo, gi, asg) = &cand_cells[i];
            eval(combo, gammas[*gi], asg)
        });
        for (&i, plan) in batch.iter().zip(results) {
            if let Some(p) = plan {
                if improves(p.cost_yr, incumbent.as_ref().map(|(_, b)| b.cost_yr)) {
                    incumbent = Some((cand_cells[i].clone(), p));
                }
            }
        }
        explored += batch.len();
        next = end;
    }
    // Whatever the budget or deadline left unevaluated is the frontier
    // the reported gap is measured against.
    let frontier_min_lb = order[next..]
        .iter()
        .filter_map(|&i| lbs[i])
        .fold(f64::INFINITY, f64::min);

    let Some(((mut combo, mut gi, mut asg), mut best)) = incumbent else {
        return Err(SizingError::NoFeasibleTiering { k });
    };

    // Compression: first-improvement coordinate descent in a fixed scan
    // order (tier SKUs, then boundaries ±1 step, then gamma ±1 step).
    // Re-evaluating an already-seen cell is deterministic and harmless,
    // so no visited-set is consulted.
    let mut pos: Vec<usize> = combo
        .iter()
        .map(|b| cands.iter().position(|c| c == b).expect("combo on grid"))
        .collect();
    'rounds: for _ in 0..cfg.compress_rounds {
        let mut improved = false;
        for t in 0..k {
            for sv in 0..s {
                if sv == asg[t] {
                    continue;
                }
                if deadline.expired() {
                    break 'rounds;
                }
                let mut na = asg.clone();
                na[t] = sv;
                if let Some(p) = eval(&combo, gammas[gi], &na) {
                    if p.cost_yr < best.cost_yr - 1e-9 {
                        asg = na;
                        best = p;
                        improved = true;
                    }
                }
            }
        }
        for j in 0..k - 1 {
            for d in [-1i64, 1] {
                if deadline.expired() {
                    break 'rounds;
                }
                let np = pos[j] as i64 + d;
                if np < 0 || np >= cands.len() as i64 {
                    continue;
                }
                let mut npos = pos.clone();
                npos[j] = np as usize;
                if !npos.windows(2).all(|w| w[1] > w[0]) {
                    continue;
                }
                let nc: Vec<u32> = npos.iter().map(|&p| cands[p]).collect();
                if let Some(p) = eval(&nc, gammas[gi], &asg) {
                    if p.cost_yr < best.cost_yr - 1e-9 {
                        pos = npos;
                        combo = nc;
                        best = p;
                        improved = true;
                    }
                }
            }
        }
        for d in [-1i64, 1] {
            if deadline.expired() {
                break 'rounds;
            }
            let ng = gi as i64 + d;
            if ng < 0 || ng >= gammas.len() as i64 {
                continue;
            }
            if let Some(p) = eval(&combo, gammas[ng as usize], &asg) {
                if p.cost_yr < best.cost_yr - 1e-9 {
                    gi = ng as usize;
                    best = p;
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }

    let bound_gap_pct = if frontier_min_lb.is_finite() && frontier_min_lb < best.cost_yr {
        (best.cost_yr - frontier_min_lb) / best.cost_yr * 100.0
    } else {
        0.0
    };
    Ok(AnytimeResult {
        plan: best,
        cells_evaluated: evals.load(Ordering::Relaxed) + plain_evals,
        bound_gap_pct,
        exact: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traces;

    fn azure_input() -> PlanInput {
        let mut i = PlanInput::new(traces::azure(), 1000.0);
        i.cfg.mc_samples = 8_000;
        i
    }

    #[test]
    fn no_catalog_delegates_to_pruned_sweep_bitwise() {
        let input = azure_input();
        let (oracle, _) = sweep_tiered_pruned(&input, 3, &CalibCache::new()).unwrap();
        let r = anytime_search(
            &input,
            3,
            None,
            &CalibCache::new(),
            Deadline::none(),
            &AnytimeConfig::default(),
        )
        .unwrap();
        assert!(r.exact);
        assert_eq!(r.bound_gap_pct, 0.0);
        assert_eq!(r.plan.cost_yr.to_bits(), oracle.cost_yr.to_bits());
        assert_eq!(r.plan.boundaries(), oracle.boundaries());
        assert_eq!(r.plan.gpu_counts(), oracle.gpu_counts());
    }

    #[test]
    fn small_mixed_space_delegates_to_exact_sku_sweep() {
        let input = azure_input();
        let catalog = SkuCatalog::demo(&input.gpu);
        // K=2 demo space: 132 boundary-gamma cells x 9 assignments.
        assert!(sku_sweep_space(&input, 2, &catalog) <= 4096);
        let (oracle, _) =
            sweep_tiered_skus_pruned(&input, 2, &catalog, &CalibCache::new()).unwrap();
        let r = anytime_search(
            &input,
            2,
            Some(&catalog),
            &CalibCache::new(),
            Deadline::none(),
            &AnytimeConfig::default(),
        )
        .unwrap();
        assert!(r.exact);
        assert_eq!(r.plan.cost_yr.to_bits(), oracle.cost_yr.to_bits());
        assert_eq!(r.plan.boundaries(), oracle.boundaries());
        assert_eq!(r.plan.gpu_counts(), oracle.gpu_counts());
    }

    #[test]
    fn sampled_search_is_seed_deterministic_and_beats_single_sku() {
        let input = azure_input();
        let catalog = SkuCatalog::demo(&input.gpu);
        // Force the sampled path even on this small space.
        let cfg = AnytimeConfig {
            explore_cells: 32,
            exhaustive_cells: 0,
            ..AnytimeConfig::default()
        };
        let run = || {
            anytime_search(
                &input,
                2,
                Some(&catalog),
                &CalibCache::new(),
                Deadline::none(),
                &cfg,
            )
            .unwrap()
        };
        let a = run();
        let b = run();
        assert!(!a.exact);
        assert_eq!(a.plan.cost_yr.to_bits(), b.plan.cost_yr.to_bits());
        assert_eq!(a.plan.boundaries(), b.plan.boundaries());
        assert_eq!(a.plan.gpu_counts(), b.plan.gpu_counts());
        assert_eq!(a.cells_evaluated, b.cells_evaluated);
        assert_eq!(a.bound_gap_pct.to_bits(), b.bound_gap_pct.to_bits());
        // Phase 0 seeds the uniform-base assignment at the plain argmin,
        // so mixed can never lose to single-SKU.
        let (plain, _) = sweep_tiered_pruned(&input, 2, &CalibCache::new()).unwrap();
        assert!(a.plan.cost_yr <= plain.cost_yr + 1e-9);
    }

    #[test]
    fn deadline_truncates_but_still_returns_a_plan() {
        let input = azure_input();
        let catalog = SkuCatalog::demo(&input.gpu);
        let cfg = AnytimeConfig {
            explore_cells: usize::MAX / 8,
            exhaustive_cells: 0,
            ..AnytimeConfig::default()
        };
        let started = std::time::Instant::now();
        let r = anytime_search(
            &input,
            2,
            Some(&catalog),
            &CalibCache::new(),
            Deadline::after_ms(1),
            &cfg,
        )
        .unwrap();
        // Phase 0 always completes (the incumbent guarantee), the rest is
        // truncated: well under the unbounded run's work, and quickly.
        assert!(r.plan.cost_yr.is_finite());
        assert!(started.elapsed() < Duration::from_secs(30));
    }

    #[test]
    fn zero_explore_budget_reports_frontier_gap() {
        let input = azure_input();
        let catalog = SkuCatalog::demo(&input.gpu);
        let cfg = AnytimeConfig {
            explore_cells: 0, // evaluate nothing beyond phase 0
            compress_rounds: 0,
            exhaustive_cells: 0,
            ..AnytimeConfig::default()
        };
        let r = anytime_search(
            &input,
            2,
            Some(&catalog),
            &CalibCache::new(),
            Deadline::none(),
            &cfg,
        )
        .unwrap();
        assert!(r.bound_gap_pct >= 0.0);
        assert!(!r.exact);
    }
}
