//! Marginal-cost analysis: the equal-marginal-GPU-cost first-order
//! condition that characterizes the optimal boundary (paper §4.2, Prop. 1,
//! App. B).
//!
//! Under rho_max-dominated sizing, `dn*/dlambda ~ 1/(rho_max mu_gpu)`, so
//! the FOC `c_s dn_s/dlambda_s = c_l dn_l/dlambda_l` reduces to
//! `c_s / mu_s = c_l / mu_l` (per-GPU). The sweep finds the integer-optimal
//! boundary; this module exposes the continuous FOC so benches can verify
//! the optimum sits where the marginal-cost gap changes sign.

use crate::config::GpuProfile;
use crate::planner::sweep::{plan_fleet, PlanInput};
use crate::queueing::service::ServiceStats;

/// Marginal GPU cost of one additional req/s into a pool, $/hr per (req/s):
/// `cost_hr * dn/dlambda` with the continuous relaxation of Eq. 11.
pub fn marginal_cost(svc: &ServiceStats, cost_hr: f64, rho_max: f64) -> f64 {
    cost_hr / (rho_max * svc.mu_gpu())
}

/// The FOC gap at a boundary: marginal short-pool cost minus marginal
/// long-pool saving (Eq. 12's bracketed term, scaled by the GPU costs).
/// Negative gap => routing more traffic short still pays; the optimum is
/// where the gap crosses zero (or at the grid edge if it never does).
pub fn foc_gap(input: &PlanInput, b_short: u32, gamma: f64) -> Option<f64> {
    let plan = plan_fleet(input, b_short, gamma).ok()?;
    let g: &GpuProfile = &input.gpu;
    let s = plan.short.svc.as_ref()?;
    let l = plan.long.svc.as_ref()?;
    Some(
        marginal_cost(s, g.cost_short_hr, input.cfg.rho_max)
            - marginal_cost(l, g.cost_long_hr, input.cfg.rho_max),
    )
}

/// Evaluate the FOC gap across candidate boundaries (for Prop. 1 reporting).
pub fn foc_profile(input: &PlanInput, candidates: &[u32], gamma: f64) -> Vec<(u32, f64)> {
    candidates
        .iter()
        .filter_map(|&b| foc_gap(input, b, gamma).map(|g| (b, g)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::sweep::candidate_boundaries;
    use crate::workload::traces;

    #[test]
    fn marginal_cost_scales_inverse_mu() {
        let w = traces::azure();
        let g = GpuProfile::a100_llama70b();
        let svc =
            crate::queueing::service::calibrate(&w.cdf, &w.output, &g, 16, 5_000, 1);
        let m = marginal_cost(&svc, 2.21, 0.85);
        assert!((m - 2.21 / (0.85 * svc.mu_gpu())).abs() < 1e-12);
        // Cheaper pools (higher mu) have lower marginal cost.
        let svc_fast =
            crate::queueing::service::calibrate(&w.cdf, &w.output, &g, 256, 5_000, 1);
        assert!(marginal_cost(&svc_fast, 2.21, 0.85) < m);
    }

    #[test]
    fn short_pool_marginally_cheaper_at_paper_boundary() {
        // The whole premise of pool routing: at the evaluation boundary the
        // short pool's marginal GPU cost per req/s is below the long pool's.
        let mut input = PlanInput::new(traces::azure(), 1000.0);
        input.cfg.mc_samples = 8_000;
        let gap = foc_gap(&input, 4096, 1.0).unwrap();
        assert!(gap < 0.0, "gap={gap}");
    }

    #[test]
    fn foc_profile_covers_candidates() {
        let mut input = PlanInput::new(traces::agent_heavy(), 1000.0);
        input.cfg.mc_samples = 5_000;
        let cands = candidate_boundaries(&input);
        let prof = foc_profile(&input, &cands, 1.0);
        assert_eq!(prof.len(), cands.len());
        // For these homogeneous-cost workloads the short pool is marginally
        // cheaper at every hardware-feasible boundary (the FOC gap never
        // crosses zero) — the regime where the planner pushes the effective
        // boundary up via gamma instead, consistent with gamma* -> 2.0
        // (paper §4.3).
        for (b, gap) in &prof {
            assert!(*gap < 0.0, "gap at B={b} should be negative: {prof:?}");
        }
    }
}
