//! The FleetOpt offline planner — Algorithm 1 (paper §6).
//!
//! For each candidate boundary `B` and compression bandwidth `gamma`, the
//! planner computes the post-compression split `alpha' = F(B) +
//! (F(gamma B) - F(B)) p_c`, recalibrates both pools' service rates from
//! the restricted distributions, inverts Erlang-C per pool (Eq. 11), and
//! returns the cost-minimal `(n_s*, n_l*, B*, gamma*)`.
//!
//! The critical step (paper §6 "Critical") is long-pool recalibration from
//! `F` restricted to `(gamma B, inf)` — compressing borderline traffic out
//! of the long pool *hardens* the residual distribution (longer mean, lower
//! mu_l); skipping it systematically overestimates the savings of large
//! gamma. `plan_fleet_no_recalibration` exists precisely to reproduce that
//! error in the ablation bench.
//!
//! ## §Perf: the sub-millisecond planner (moment tables + bound-and-prune)
//!
//! The paper's headline engineering claim is a sub-1 ms planner. Three
//! mechanisms deliver it without changing a single selected plan:
//!
//! 1. **Moment tables** ([`crate::queueing::service::MomentTable`]): a
//!    one-time pass over the `AnchoredCdf` builds prefix tables of the
//!    restricted service-time moments at integer token resolution, so any
//!    truncation cut's `E[S]`/SCV is an O(log n) lookup — the exact
//!    integral the per-cell quadrature converges to, with a *provable*
//!    bound on the finite-resolution gap. The quadrature stays the
//!    equivalence oracle (the `SimilarityMode::AllPairs` /
//!    `QueueImpl::BinaryHeap` pattern): evaluated cells keep it, so plans
//!    are bit-identical to the pre-refactor planner; the table powers the
//!    prune bounds below and the opt-in `CellStatsMode::MomentTable`.
//! 2. **Bound-and-prune** (`planner::tiered::sweep_tiered_pruned`): a
//!    closed-form lower bound on per-cell cost — the stability bound
//!    `n_i >= ceil(a_i / rho_max)` priced at the tier rates, using the
//!    table's error-adjusted `E[S]` lower bound, no Erlang-C — lets the
//!    sweep skip cells provably worse than an exactly-evaluated
//!    incumbent. Pruned cells cannot win under the grid-order tie-break
//!    (the margin dwarfs the 1e-9 tie band), so the argmin, its GPU
//!    counts and its cost are bit-identical to the full sweep
//!    (property-tested on all three traces at K = 2, 3, 4).
//! 3. **Warm-started inversion** (`planner::sizing`): the Erlang-C
//!    bisection brackets from the neighbouring cell's result — valid by
//!    W99 monotonicity, bit-identical by construction.
//!
//! CI enforces the resulting floors: single `plan_fleet` < 1 ms and the
//! full K = 3 bound-and-prune sweep < 10 ms in release, superseding the
//! former 100 ms gate (`BENCH_planner.json`).

use std::sync::Mutex;

use crate::config::{CellStatsMode, GpuProfile, PlannerConfig, Slo};
use crate::planner::cost::fleet_cost_yr;
use crate::planner::sizing::{min_gpus, SizingError};
use crate::queueing::mgc::PoolModel;
use crate::queueing::service::{calibrate_quadrature, MomentTable, ServiceStats};
use crate::util::hash::FxHashMap;
use crate::workload::cdf::{LengthDist, TruncatedDist};
use crate::workload::traces::Workload;

/// Memo of calibrated service stats keyed by (cut-lo bits, cut-hi bits,
/// n_slots). Within a sweep, the short pool's stats depend only on B and
/// the long pool's only on gamma*B, so most (B, gamma) cells share
/// calibrations (§Perf: this plus quadrature brings the full sweep from
/// ~430 ms to low single-digit ms).
///
/// The map is FxHash-keyed (integer tuple keys don't need SipHash) and
/// Mutex-wrapped so one merged cache is shared across the sweep's worker
/// threads: calibration is deterministic, so whichever worker computes a
/// cell first inserts the exact value every other worker would have —
/// results are bit-identical to the serial sweep regardless of schedule.
///
/// The key is deliberately SKU-free: calibration always runs at the base
/// profile's unit rate, and a tier's SKU rate multiplier is applied as a
/// pure time dilation *after* lookup ([`ServiceStats::scaled_mu`], an
/// identity at `mu_scale = 1`). Tiers on different SKUs with the same cut
/// and slot shape therefore share one cached calibration, and mixing SKUs
/// into a sweep can never perturb a single-SKU cell's cached value.
#[derive(Debug, Default)]
pub struct CalibCache {
    map: Mutex<FxHashMap<(u64, u64, u32, u8), ServiceStats>>,
}

impl CalibCache {
    pub fn new() -> Self {
        Self::default()
    }

    fn get(&self, key: &(u64, u64, u32, u8)) -> Option<ServiceStats> {
        self.map.lock().expect("calib cache poisoned").get(key).copied()
    }

    fn insert(&self, key: (u64, u64, u32, u8), svc: ServiceStats) {
        self.map.lock().expect("calib cache poisoned").insert(key, svc);
    }

    /// Number of distinct calibrations memoized (diagnostics).
    pub fn len(&self) -> usize {
        self.map.lock().expect("calib cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Planner inputs: one workload at one arrival rate under one GPU profile.
#[derive(Clone, Debug)]
pub struct PlanInput {
    pub workload: Workload,
    /// Fleet arrival rate, req/s (paper default 1,000).
    pub lambda: f64,
    pub slo: Slo,
    pub gpu: GpuProfile,
    pub cfg: PlannerConfig,
    /// Eq. 8 verbatim vs paper-consistent sizing (see `planner::sizing`).
    pub strict_slo: bool,
    /// N+k redundancy per tier: `redundancy[i]` spare GPUs are added to
    /// tier `i`'s sized count so the tier survives that many concurrent
    /// failures at full capacity. Empty (the default) means k = 0
    /// everywhere — bit-identical to the pre-redundancy planner; a single
    /// entry broadcasts to every tier. Spares are priced through the same
    /// closed-form lower bound the sweep prunes with, so pruning stays
    /// exact (`tests/planner_fastpath.rs` idiom).
    pub redundancy: Vec<u64>,
    /// KV-capacity-aware sizing: each tier's GPU count is floored at the
    /// closed-form stability bound `rho_kv < rho_max` (Little's law over
    /// full-residency `l_in + l_out` reservations — see
    /// [`crate::queueing::kv`]), with per-GPU capacity
    /// `cap_frac * n_max * c_max` tokens. `None` (the default) skips the
    /// floor — bit-identical to the KV-unconstrained planner. The floor
    /// only ever *raises* exact per-cell costs, so the KV-blind
    /// closed-form lower bound stays admissible and pruning stays exact.
    pub kv: Option<crate::queueing::kv::KvPlanPolicy>,
}

impl PlanInput {
    pub fn new(workload: Workload, lambda: f64) -> Self {
        PlanInput {
            workload,
            lambda,
            slo: Slo::default(),
            gpu: GpuProfile::a100_llama70b(),
            cfg: PlannerConfig::default(),
            strict_slo: false,
            redundancy: Vec::new(),
            kv: None,
        }
    }
}

/// One provisioned pool in a plan.
#[derive(Clone, Debug)]
pub struct PoolPlan {
    pub n_gpus: u64,
    pub lambda: f64,
    pub svc: Option<ServiceStats>,
}

impl PoolPlan {
    pub(crate) fn empty() -> Self {
        PoolPlan {
            n_gpus: 0,
            lambda: 0.0,
            svc: None,
        }
    }

    pub fn model(&self) -> Option<PoolModel> {
        // `ServiceStats` is Copy: no clone per call (rho_ana/ttft_p99 used
        // to re-clone the stats on every diagnostic read).
        self.svc
            .as_ref()
            .filter(|_| self.n_gpus > 0)
            .map(|s| PoolModel::new(self.lambda, self.n_gpus, *s))
    }

    /// Analytical GPU utilization rho_ana (Table 5).
    pub fn rho_ana(&self) -> f64 {
        self.model().map(|m| m.rho_ana()).unwrap_or(0.0)
    }

    pub fn ttft_p99(&self) -> f64 {
        self.model().map(|m| m.ttft_p99()).unwrap_or(0.0)
    }
}

/// A complete fleet plan: the planner's output tuple plus diagnostics.
#[derive(Clone, Debug)]
pub struct Plan {
    pub b_short: u32,
    pub gamma: f64,
    pub alpha: f64,
    pub beta: f64,
    /// alpha' = alpha + beta p_c (Eq. 1).
    pub alpha_prime: f64,
    pub short: PoolPlan,
    pub long: PoolPlan,
    pub cost_yr: f64,
}

impl Plan {
    pub fn total_gpus(&self) -> u64 {
        self.short.n_gpus + self.long.n_gpus
    }
}

/// Calibrate (with memoization) the service stats for `F` restricted to
/// `[lo, hi]` at `n_slots` slots per GPU. The computation happens outside
/// the cache lock; a racing duplicate insert writes the identical value
/// (calibration is deterministic), so sharing the cache across threads
/// cannot change results.
pub(crate) fn calibrated(
    input: &PlanInput,
    cache: Option<&CalibCache>,
    lo: f64,
    hi: f64,
    n_slots: u32,
) -> ServiceStats {
    let mode = input.cfg.cell_stats;
    let key = (lo.to_bits(), hi.to_bits(), n_slots, mode as u8);
    if let Some(c) = cache {
        if let Some(s) = c.get(&key) {
            return s;
        }
    }
    let w = &input.workload;
    let svc = match mode {
        CellStatsMode::Quadrature => {
            let dist = TruncatedDist::new(w.cdf.clone(), lo, hi);
            // Budget-equivalent quadrature resolution: mc_samples maps onto
            // the (length x jitter) grid so existing configs keep their
            // fidelity knob.
            let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
            calibrate_quadrature(&dist, &w.output, &input.gpu, n_slots, len_points, 8)
        }
        CellStatsMode::MomentTable => MomentTable::for_workload(w, input.gpu.chunk)
            .stats(lo, hi, n_slots, &input.gpu)
            .expect("calibration cut must carry mass"),
    };
    if let Some(c) = cache {
        c.insert(key, svc);
    }
    svc
}

/// Plan one (B, gamma) cell of Algorithm 1.
pub fn plan_fleet(input: &PlanInput, b_short: u32, gamma: f64) -> Result<Plan, SizingError> {
    plan_cell(input, b_short, gamma, true, None)
}

/// Ablation: skip the long-pool post-compression recalibration — the long
/// pool is calibrated from the full above-B distribution instead of the
/// above-gamma-B residual (the error §6 warns against).
pub fn plan_fleet_no_recalibration(
    input: &PlanInput,
    b_short: u32,
    gamma: f64,
) -> Result<Plan, SizingError> {
    plan_cell(input, b_short, gamma, false, None)
}

thread_local! {
    /// Warm calibration store for the single-cell entry points
    /// (`plan_fleet` & co., which pass no sweep cache): repeat cells over
    /// one workload + GPU profile re-use their quadratures exactly as a
    /// sweep's shared cache would. Values are bit-identical (the cache
    /// only memoizes deterministic computations — same justification as
    /// the thread-local Erlang memo); the store is keyed by a fingerprint
    /// of everything calibration reads and resets whenever it changes.
    static CELL_CACHE: std::cell::RefCell<(u64, std::rc::Rc<CalibCache>)> =
        std::cell::RefCell::new((0, std::rc::Rc::new(CalibCache::new())));
}

/// This thread's warm single-cell calibration cache for `input` (see
/// [`CELL_CACHE`]): fingerprint = workload calibration features + the GPU
/// profile fields the quadrature reads + the resolved quadrature
/// resolution. A mismatch swaps in a fresh cache.
fn cell_cache_for(input: &PlanInput) -> std::rc::Rc<CalibCache> {
    let h = crate::util::hash::fnv1a_words(
        input.workload.fingerprint(),
        &[
            input.gpu.w_ms.to_bits(),
            input.gpu.h_ms_per_slot.to_bits(),
            input.gpu.chunk as u64,
            input.gpu.n_max_calib as u64,
            input.gpu.c_calib as u64,
            (input.cfg.mc_samples / 8).clamp(64, 512) as u64,
        ],
    );
    CELL_CACHE.with(|c| {
        let mut c = c.borrow_mut();
        if c.0 != h {
            *c = (h, std::rc::Rc::new(CalibCache::new()));
        }
        c.1.clone()
    })
}

/// One Algorithm-1 cell, evaluated as the K = 2 special case of the
/// generalized K-tier planner ([`crate::planner::tiered::plan_tiers`]) and
/// projected back into the two-pool [`Plan`] shape. The tiered path
/// performs bit-for-bit the same calibrations, shares, sizing calls and
/// cost sum as the pre-refactor two-pool code (`tests/tier_equivalence.rs`
/// holds the reference implementation as an oracle — the warm per-thread
/// store only ever returns values that path already computed).
fn plan_cell(
    input: &PlanInput,
    b_short: u32,
    gamma: f64,
    recalibrate_long: bool,
    cache: Option<&CalibCache>,
) -> Result<Plan, SizingError> {
    let spec = input.gpu.fleet_spec(&[b_short]);
    let local = match cache {
        Some(_) => None,
        None => Some(cell_cache_for(input)),
    };
    let cache = cache.or(local.as_deref());
    let tiered =
        crate::planner::tiered::plan_tiers(input, &spec, &[gamma], recalibrate_long, cache)?;
    Ok(tiered.into_two_pool())
}

/// The homogeneous baseline (§7.1 baseline 1): a single pool sized for the
/// full `C_max^(l)` context window serving all traffic.
pub fn plan_homogeneous(input: &PlanInput) -> Result<Plan, SizingError> {
    let w = &input.workload;
    let g = &input.gpu;
    let len_points = (input.cfg.mc_samples / 8).clamp(64, 512);
    let svc = calibrate_quadrature(
        &w.cdf,
        &w.output,
        g,
        g.n_max_long(),
        len_points,
        8,
    );
    let n = min_gpus(
        input.lambda,
        &svc,
        input.slo.p99_ttft_s,
        input.cfg.rho_max,
        input.strict_slo,
    )?;
    Ok(Plan {
        b_short: 0,
        gamma: 1.0,
        alpha: 0.0,
        beta: 0.0,
        alpha_prime: 0.0,
        short: PoolPlan::empty(),
        cost_yr: fleet_cost_yr(0, n, g),
        long: PoolPlan {
            n_gpus: n,
            lambda: input.lambda,
            svc: Some(svc),
        },
    })
}

/// Generic sharded map for sweep grids: evaluate `f` over `items`,
/// optionally split across workers (§Perf). Delegates to the shared
/// [`crate::util::par::par_map`] substrate — contiguous chunks, >= 4
/// cells per worker (the full sweep is only milliseconds, so oversharding
/// would give the gain back to thread startup), capped by
/// `FLEETOPT_THREADS` / `--threads`. Results are returned in input order
/// and are bit-identical to the serial evaluation whenever `f` is
/// deterministic — the planner's shared [`CalibCache`] only memoizes
/// values every worker would compute identically. Shared by the
/// (B, gamma) sweep and the K-tier boundary sweep (`planner::tiered`).
pub(crate) fn par_map<T: Sync, R: Send>(
    items: &[T],
    parallel: bool,
    f: impl Fn(&T) -> Result<R, SizingError> + Sync,
) -> Result<Vec<R>, SizingError> {
    crate::util::par::par_map(items, parallel, f)
}

/// Evaluate Algorithm-1 cells (recalibrating long pools) against one
/// merged calibration cache.
fn plan_cells(
    input: &PlanInput,
    cache: &CalibCache,
    cells: &[(u32, f64)],
    parallel: bool,
) -> Result<Vec<Plan>, SizingError> {
    par_map(cells, parallel, |&(b, gamma)| {
        plan_cell(input, b, gamma, true, Some(cache))
    })
}

/// The serial best-plan selection rule: first strictly-better (by > 1e-9)
/// cell in grid order wins, so ties break toward earlier cells — smaller B,
/// then smaller gamma ("compress more" must strictly pay to be chosen).
fn select_best(plans: Vec<Plan>) -> Option<Plan> {
    let mut best: Option<Plan> = None;
    for plan in plans {
        let better = match &best {
            None => true,
            Some(b) => plan.cost_yr < b.cost_yr - 1e-9,
        };
        if better {
            best = Some(plan);
        }
    }
    best
}

/// Sweep gamma at a fixed boundary (Table 3's FleetOpt rows: the workload's
/// B_short with gamma* from the sweep). Ties break toward smaller gamma so
/// "compress more" must strictly pay to be chosen. Runs the gamma grid in
/// parallel; results are bit-identical to [`sweep_gamma_serial`].
pub fn sweep_gamma(input: &PlanInput, b_short: u32) -> Result<Plan, SizingError> {
    sweep_gamma_with(input, b_short, true)
}

/// Single-threaded [`sweep_gamma`] (equivalence oracle / small hosts).
pub fn sweep_gamma_serial(input: &PlanInput, b_short: u32) -> Result<Plan, SizingError> {
    sweep_gamma_with(input, b_short, false)
}

fn sweep_gamma_with(
    input: &PlanInput,
    b_short: u32,
    parallel: bool,
) -> Result<Plan, SizingError> {
    let cache = CalibCache::new();
    let cells: Vec<(u32, f64)> = input.cfg.gammas.iter().map(|&g| (b_short, g)).collect();
    let plans = plan_cells(input, &cache, &cells, parallel)?;
    Ok(select_best(plans).expect("gamma grid must be non-empty"))
}

/// Hardware-feasible candidate boundaries (paper §6 "Candidate set B"):
/// values inside the CDF support that yield a valid short-pool slot count
/// strictly above the long pool's.
pub fn candidate_boundaries(input: &PlanInput) -> Vec<u32> {
    const GRID: [u32; 12] = [
        512, 1024, 1536, 2048, 3072, 4096, 6144, 8192, 12288, 16384, 24576, 32768,
    ];
    let w = &input.workload;
    let g = &input.gpu;
    GRID.iter()
        .copied()
        .filter(|&b| {
            (b as f64) > w.cdf.min_tokens()
                && (b as f64) < w.cdf.max_tokens()
                && b < g.c_max_long
                && g.n_max(b) > g.n_max_long()
                && w.cdf.cdf(b as f64) > 0.0
        })
        .collect()
}

/// Full Algorithm 1: outer sweep over candidate boundaries, inner over
/// gamma. Returns the global optimum and the per-(B, gamma) cost grid for
/// reporting. The (B, gamma) grid is sharded across scoped threads with a
/// merged calibration cache (§Perf); grid order, cost values, and the
/// selected optimum are bit-identical to [`sweep_full_serial`]
/// (property-tested).
pub fn sweep_full(input: &PlanInput) -> Result<(Plan, Vec<(u32, f64, f64)>), SizingError> {
    sweep_full_with(input, true)
}

/// Single-threaded [`sweep_full`] (equivalence oracle / small hosts).
pub fn sweep_full_serial(
    input: &PlanInput,
) -> Result<(Plan, Vec<(u32, f64, f64)>), SizingError> {
    sweep_full_with(input, false)
}

fn sweep_full_with(
    input: &PlanInput,
    parallel: bool,
) -> Result<(Plan, Vec<(u32, f64, f64)>), SizingError> {
    let candidates = candidate_boundaries(input);
    assert!(!candidates.is_empty(), "no feasible boundaries");
    let cache = CalibCache::new();
    let mut cells = Vec::with_capacity(candidates.len() * input.cfg.gammas.len());
    for &b in &candidates {
        for &gamma in &input.cfg.gammas {
            cells.push((b, gamma));
        }
    }
    let plans = plan_cells(input, &cache, &cells, parallel)?;
    let grid: Vec<(u32, f64, f64)> = cells
        .iter()
        .zip(&plans)
        .map(|(&(b, gamma), plan)| (b, gamma, plan.cost_yr))
        .collect();
    let best = select_best(plans).expect("non-empty grid");
    Ok((best, grid))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traces;

    fn azure_input() -> PlanInput {
        let mut i = PlanInput::new(traces::azure(), 1000.0);
        i.cfg.mc_samples = 8_000; // keep unit tests fast
        i
    }

    #[test]
    fn traffic_split_conserved() {
        let input = azure_input();
        let p = plan_fleet(&input, 4096, 1.5).unwrap();
        assert!((p.short.lambda + p.long.lambda - 1000.0).abs() < 1e-9);
        assert!((p.alpha_prime - (p.alpha + p.beta * 1.0)).abs() < 1e-12);
    }

    #[test]
    fn gamma_one_means_no_compression() {
        let input = azure_input();
        let p = plan_fleet(&input, 4096, 1.0).unwrap();
        assert_eq!(p.beta, 0.0);
        assert!((p.alpha_prime - p.alpha).abs() < 1e-12);
        assert!((p.short.lambda - 0.898 * 1000.0).abs() < 0.1);
    }

    #[test]
    fn pool_routing_beats_homogeneous_on_azure() {
        let input = azure_input();
        let homo = plan_homogeneous(&input).unwrap();
        let pr = plan_fleet(&input, 4096, 1.0).unwrap();
        assert!(
            pr.cost_yr < homo.cost_yr,
            "PR {} vs homo {}",
            pr.cost_yr,
            homo.cost_yr
        );
    }

    #[test]
    fn compression_beats_plain_pool_routing_on_azure() {
        let input = azure_input();
        let pr = plan_fleet(&input, 4096, 1.0).unwrap();
        let cr = plan_fleet(&input, 4096, 1.5).unwrap();
        assert!(cr.cost_yr < pr.cost_yr);
        // And the long pool shrank (that's where the savings come from).
        assert!(cr.long.n_gpus < pr.long.n_gpus);
    }

    #[test]
    fn sweep_gamma_never_worse_than_retrofit() {
        // Theorem 2: co-design <= retrofit at any fixed gamma in the grid.
        let input = azure_input();
        let retrofit = plan_fleet(&input, 4096, 1.5).unwrap();
        let best = sweep_gamma(&input, 4096).unwrap();
        assert!(best.cost_yr <= retrofit.cost_yr + 1e-9);
    }

    #[test]
    fn azure_prefers_max_gamma() {
        // Paper §6: Archetype I/II workloads push gamma* to 2.0.
        let input = azure_input();
        let best = sweep_gamma(&input, 4096).unwrap();
        assert!(
            best.gamma >= 1.9,
            "expected gamma* ~ 2.0 for Azure, got {}",
            best.gamma
        );
    }

    #[test]
    fn recalibration_matters() {
        // §6 "Critical": skipping mu_l recalibration must make large gamma
        // look at least as good (never worse) => cost estimate <= correct.
        let input = azure_input();
        let correct = plan_fleet(&input, 4096, 2.0).unwrap();
        let wrong = plan_fleet_no_recalibration(&input, 4096, 2.0).unwrap();
        assert!(wrong.long.n_gpus <= correct.long.n_gpus);
    }

    #[test]
    fn candidates_respect_hardware_granularity() {
        let input = azure_input();
        let cands = candidate_boundaries(&input);
        assert!(cands.contains(&4096));
        assert!(!cands.is_empty() && cands.len() <= 15);
        for b in cands {
            assert!(input.gpu.n_max(b) > input.gpu.n_max_long());
        }
    }

    #[test]
    fn full_sweep_at_least_as_good_as_fixed_boundary() {
        let input = azure_input();
        let fixed = sweep_gamma(&input, 4096).unwrap();
        let (best, grid) = sweep_full(&input).unwrap();
        assert!(best.cost_yr <= fixed.cost_yr + 1e-9);
        assert!(grid.len() >= 11);
    }

    #[test]
    fn parallel_sweeps_bit_identical_to_serial() {
        let input = azure_input();
        let (bp, gp) = sweep_full(&input).unwrap();
        let (bs, gs) = sweep_full_serial(&input).unwrap();
        assert_eq!(gp, gs, "cost grids must match bit-for-bit");
        assert_eq!(bp.cost_yr, bs.cost_yr);
        assert_eq!((bp.b_short, bp.gamma), (bs.b_short, bs.gamma));
        assert_eq!(bp.short.n_gpus, bs.short.n_gpus);
        assert_eq!(bp.long.n_gpus, bs.long.n_gpus);

        let fp = sweep_gamma(&input, 4096).unwrap();
        let fs = sweep_gamma_serial(&input, 4096).unwrap();
        assert_eq!(fp.cost_yr, fs.cost_yr);
        assert_eq!(fp.gamma, fs.gamma);
    }

    #[test]
    fn plans_are_deterministic() {
        let input = azure_input();
        let a = plan_fleet(&input, 4096, 1.5).unwrap();
        let b = plan_fleet(&input, 4096, 1.5).unwrap();
        assert_eq!(a.short.n_gpus, b.short.n_gpus);
        assert_eq!(a.long.n_gpus, b.long.n_gpus);
        assert_eq!(a.cost_yr, b.cost_yr);
    }

    #[test]
    fn pools_run_near_rho_max() {
        // §7.4: sizing is rho_max-dominated; both pools sit just under 0.85.
        let input = azure_input();
        let p = plan_fleet(&input, 4096, 1.0).unwrap();
        for pool in [&p.short, &p.long] {
            let rho = pool.rho_ana();
            assert!(
                rho > 0.6 && rho <= 0.8501,
                "pool rho {rho} not near the cap"
            );
        }
    }
}
