//! The FleetOpt offline planner (paper §4, §6): per-pool Erlang-C sizing,
//! the Algorithm-1 (B, gamma) sweep with long-pool recalibration, the cost
//! model, the Prop.-1 marginal-cost analysis, the K-tier generalization
//! ([`tiered`]) of which the paper's two-pool planner is the K = 2 special
//! case, and the online incremental replanner with hysteresis ([`replan`])
//! that turns the one-shot plan into a control loop.

pub mod anytime;
pub mod cost;
pub mod marginal;
pub mod replan;
pub mod sizing;
pub mod sweep;
pub mod tiered;

pub use anytime::{anytime_search, AnytimeConfig, AnytimeResult, Deadline};
pub use replan::{ReplanConfig, ReplanOutcome, Replanner};
pub use sweep::{
    candidate_boundaries, plan_fleet, plan_fleet_no_recalibration, plan_homogeneous,
    sweep_full, sweep_full_serial, sweep_gamma, sweep_gamma_serial, CalibCache, Plan,
    PlanInput, PoolPlan,
};
pub use tiered::{
    layout_neighborhood, plan_spec_sweep_gamma, plan_spec_sweep_gamma_cached, plan_tiers,
    sku_assignments, sku_sweep_space, sweep_cell_bounds, sweep_tiered, sweep_tiered_cached,
    sweep_tiered_pruned, sweep_tiered_pruned_seeded, sweep_tiered_serial,
    sweep_tiered_skus_pruned, PruneStats, TierCell, TieredPlan,
};
