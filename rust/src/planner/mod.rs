//! The FleetOpt offline planner (paper §4, §6): per-pool Erlang-C sizing,
//! the Algorithm-1 (B, gamma) sweep with long-pool recalibration, the cost
//! model, and the Prop.-1 marginal-cost analysis.

pub mod cost;
pub mod marginal;
pub mod sizing;
pub mod sweep;

pub use sweep::{
    candidate_boundaries, plan_fleet, plan_fleet_no_recalibration, plan_homogeneous,
    sweep_full, sweep_full_serial, sweep_gamma, sweep_gamma_serial, CalibCache, Plan,
    PlanInput, PoolPlan,
};
