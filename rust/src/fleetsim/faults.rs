//! Deterministic failure injection for the fleet DES (chaos testing).
//!
//! A [`FaultPlan`] is a pure description of the failure processes a run
//! injects: per-replica crash–restart (exponential MTBF/MTTR), scheduled
//! whole-tier outages, and spot preemptions for `preemptible` SKUs. It is
//! deterministic by construction — every GPU draws its failure times from
//! its own seeded stream keyed by `(plan seed, tier, gpu index)`, so the
//! same plan against the same fleet produces the same fault trace
//! regardless of event interleaving, and a disabled plan injects nothing
//! (the DES is then bit-identical to a run without chaos wired in at all;
//! property-tested in `tests/chaos_conservation.rs`).
//!
//! GPU slots in the simulators are append-only (retired GPUs keep their
//! index), so the `(tier, gpu index)` key never aliases two machines.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-replica crash–restart process: exponential time-to-failure with
/// mean `mtbf_s`, fixed repair time `mttr_s`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicaFaults {
    pub mtbf_s: f64,
    pub mttr_s: f64,
}

/// Spot-preemption process, applied only to GPUs on `preemptible` SKUs:
/// exponential time-to-preemption with mean `mtbp_s`, reclaim `mttr_s`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpotFaults {
    pub mtbp_s: f64,
    pub mttr_s: f64,
}

/// One scheduled whole-tier outage window.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierOutage {
    pub tier: usize,
    pub start_s: f64,
    pub duration_s: f64,
}

/// A seeded, deterministic fault plan (see module docs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub replica: Option<ReplicaFaults>,
    pub spot: Option<SpotFaults>,
    pub outages: Vec<TierOutage>,
}

/// One drawn failure: it strikes `dt_s` after the draw point and takes
/// `mttr_s` to repair (restart additionally pays the simulator's
/// provisioning delay where one exists).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureDraw {
    pub dt_s: f64,
    pub mttr_s: f64,
    /// True for a spot preemption, false for a replica crash.
    pub preemption: bool,
}

fn fault_f64(j: &Json, key: &str) -> Result<f64> {
    let v = j
        .get(key)
        .with_context(|| format!("fault plan: missing `{key}`"))?
        .as_f64()
        .with_context(|| format!("fault plan: `{key}` must be a number"))?;
    if !(v > 0.0) || !v.is_finite() {
        bail!("fault plan: `{key}` must be finite and > 0, got {v}");
    }
    Ok(v)
}

impl FaultPlan {
    /// Parse the chaos-plan JSON schema (see `examples/configs/
    /// chaos_plan.json` and the README "Failure model" section):
    ///
    /// ```json
    /// {
    ///   "seed": 7,
    ///   "replica": {"mtbf_s": 300.0, "mttr_s": 5.0},
    ///   "spot":    {"mtbp_s": 600.0, "mttr_s": 20.0},
    ///   "outages": [{"tier": 1, "start_s": 60.0, "duration_s": 20.0}]
    /// }
    /// ```
    ///
    /// `replica`, `spot`, and `outages` are each optional; an empty object
    /// is a valid (inert) plan.
    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        let seed = j.get("seed").and_then(|s| s.as_f64()).unwrap_or(0.0) as u64;
        let replica = match j.get("replica") {
            Some(r) => Some(ReplicaFaults {
                mtbf_s: fault_f64(r, "mtbf_s")?,
                mttr_s: fault_f64(r, "mttr_s")?,
            }),
            None => None,
        };
        let spot = match j.get("spot") {
            Some(s) => Some(SpotFaults {
                mtbp_s: fault_f64(s, "mtbp_s")?,
                mttr_s: fault_f64(s, "mttr_s")?,
            }),
            None => None,
        };
        let mut outages = Vec::new();
        if let Some(arr) = j.get("outages") {
            let arr = arr
                .as_arr()
                .context("fault plan: `outages` must be an array")?;
            for o in arr {
                let tier = o
                    .get("tier")
                    .and_then(|t| t.as_usize())
                    .context("fault plan: outage needs an integer `tier`")?;
                let start_s = o
                    .get("start_s")
                    .and_then(|t| t.as_f64())
                    .context("fault plan: outage needs `start_s`")?;
                if start_s < 0.0 || !start_s.is_finite() {
                    bail!("fault plan: outage start_s must be >= 0, got {start_s}");
                }
                outages.push(TierOutage {
                    tier,
                    start_s,
                    duration_s: fault_f64(o, "duration_s")?,
                });
            }
        }
        Ok(FaultPlan {
            seed,
            replica,
            spot,
            outages,
        })
    }

    /// Load a plan from a JSON file (the `--chaos` CLI path).
    pub fn from_file(path: &str) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        FaultPlan::from_json(&j)
    }

    /// Whether any GPU-level process applies to a GPU on a tier with the
    /// given preemptibility.
    pub fn has_gpu_faults(&self, preemptible: bool) -> bool {
        self.replica.is_some() || (preemptible && self.spot.is_some())
    }

    /// The independent failure stream for GPU `gpu` of tier `tier` —
    /// FNV-1a over the key, xored into the plan seed. GPU indices are
    /// append-only in both simulators, so streams never alias.
    pub fn gpu_rng(&self, tier: usize, gpu: u64) -> Rng {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in (tier as u64)
            .to_le_bytes()
            .iter()
            .chain(gpu.to_le_bytes().iter())
        {
            h = (h ^ *b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        Rng::new(self.seed ^ h)
    }

    /// Draw the next failure on one GPU's stream: the superposition of the
    /// replica-crash and (when `preemptible`) spot-preemption processes,
    /// classified by a Bernoulli split of the combined rate. Returns
    /// `None` when no process applies. Exactly two variates are consumed
    /// per draw, so streams stay aligned across configurations with the
    /// same set of active processes.
    pub fn draw(&self, rng: &mut Rng, preemptible: bool) -> Option<FailureDraw> {
        let r_crash = self.replica.map_or(0.0, |r| 1.0 / r.mtbf_s);
        let r_spot = if preemptible {
            self.spot.map_or(0.0, |s| 1.0 / s.mtbp_s)
        } else {
            0.0
        };
        let rate = r_crash + r_spot;
        if rate <= 0.0 {
            return None;
        }
        let dt_s = rng.exp(rate);
        let preemption = rng.bool(r_spot / rate);
        let mttr_s = if preemption {
            self.spot.expect("spot rate > 0").mttr_s
        } else {
            self.replica.expect("crash rate > 0").mttr_s
        };
        Some(FailureDraw {
            dt_s,
            mttr_s,
            preemption,
        })
    }

    /// Outages scheduled against tier `tier`, in start order.
    pub fn tier_outages(&self, tier: usize) -> Vec<TierOutage> {
        let mut v: Vec<TierOutage> = self
            .outages
            .iter()
            .copied()
            .filter(|o| o.tier == tier)
            .collect();
        v.sort_by(|a, b| a.start_s.total_cmp(&b.start_s));
        v
    }

    /// Project the plan onto a single pool (tier `tier`) for the one-pool
    /// simulator: `None` when nothing in the plan can touch that pool, so
    /// the caller keeps the verbatim fault-free path.
    pub fn pool(&self, tier: usize, preemptible: bool) -> Option<PoolFaultPlan> {
        let outages = self.tier_outages(tier);
        if !self.has_gpu_faults(preemptible) && outages.is_empty() {
            return None;
        }
        Some(PoolFaultPlan {
            plan: FaultPlan {
                seed: self.seed,
                replica: self.replica,
                spot: if preemptible { self.spot } else { None },
                outages,
            },
            tier,
            preemptible,
        })
    }

    /// Serialize back to the JSON schema (round-trips `from_json`).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        if let Some(r) = self.replica {
            let mut rm = BTreeMap::new();
            rm.insert("mtbf_s".to_string(), Json::Num(r.mtbf_s));
            rm.insert("mttr_s".to_string(), Json::Num(r.mttr_s));
            m.insert("replica".to_string(), Json::Obj(rm));
        }
        if let Some(s) = self.spot {
            let mut sm = BTreeMap::new();
            sm.insert("mtbp_s".to_string(), Json::Num(s.mtbp_s));
            sm.insert("mttr_s".to_string(), Json::Num(s.mttr_s));
            m.insert("spot".to_string(), Json::Obj(sm));
        }
        if !self.outages.is_empty() {
            let arr = self
                .outages
                .iter()
                .map(|o| {
                    let mut om = BTreeMap::new();
                    om.insert("tier".to_string(), Json::Num(o.tier as f64));
                    om.insert("start_s".to_string(), Json::Num(o.start_s));
                    om.insert("duration_s".to_string(), Json::Num(o.duration_s));
                    Json::Obj(om)
                })
                .collect();
            m.insert("outages".to_string(), Json::Arr(arr));
        }
        Json::Obj(m)
    }
}

/// A [`FaultPlan`] projected onto one pool (see [`FaultPlan::pool`]): the
/// single-pool simulator's view — GPU streams stay keyed by the original
/// tier index so they match the fleet-level plan machine for machine.
#[derive(Clone, Debug, PartialEq)]
pub struct PoolFaultPlan {
    plan: FaultPlan,
    tier: usize,
    preemptible: bool,
}

impl PoolFaultPlan {
    pub fn gpu_rng(&self, gpu: u64) -> Rng {
        self.plan.gpu_rng(self.tier, gpu)
    }

    pub fn draw(&self, rng: &mut Rng) -> Option<FailureDraw> {
        self.plan.draw(rng, self.preemptible)
    }

    /// This pool's outage windows, start-ordered.
    pub fn outages(&self) -> &[TierOutage] {
        &self.plan.outages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> FaultPlan {
        FaultPlan {
            seed: 7,
            replica: Some(ReplicaFaults {
                mtbf_s: 300.0,
                mttr_s: 5.0,
            }),
            spot: Some(SpotFaults {
                mtbp_s: 600.0,
                mttr_s: 20.0,
            }),
            outages: vec![TierOutage {
                tier: 1,
                start_s: 60.0,
                duration_s: 20.0,
            }],
        }
    }

    #[test]
    fn json_round_trip() {
        let p = plan();
        let q = FaultPlan::from_json(&p.to_json()).expect("round trip");
        assert_eq!(p, q);
        let empty = FaultPlan::from_json(&Json::parse("{}").unwrap()).expect("empty plan");
        assert_eq!(empty, FaultPlan::default());
        assert!(!empty.has_gpu_faults(true));
        assert!(empty.pool(0, true).is_none());
    }

    #[test]
    fn invalid_plans_are_rejected() {
        let bad = Json::parse(r#"{"replica": {"mtbf_s": -1.0, "mttr_s": 5.0}}"#).unwrap();
        assert!(FaultPlan::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"replica": {"mtbf_s": 10.0}}"#).unwrap();
        assert!(FaultPlan::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"outages": [{"tier": 0, "duration_s": 1.0}]}"#).unwrap();
        assert!(FaultPlan::from_json(&bad).is_err());
    }

    #[test]
    fn draws_are_deterministic_per_gpu_stream() {
        let p = plan();
        let mut a = p.gpu_rng(0, 3);
        let mut b = p.gpu_rng(0, 3);
        let da = p.draw(&mut a, false).expect("crash process active");
        let db = p.draw(&mut b, false).expect("crash process active");
        assert_eq!(da, db);
        assert!(!da.preemption, "non-preemptible tiers never see spot events");
        assert_eq!(da.mttr_s, 5.0);
        // Distinct GPUs get distinct streams.
        let mut c = p.gpu_rng(0, 4);
        let dc = p.draw(&mut c, false).expect("crash process active");
        assert_ne!(da.dt_s, dc.dt_s);
        // Distinct tiers too.
        let mut d = p.gpu_rng(1, 3);
        let dd = p.draw(&mut d, false).expect("crash process active");
        assert_ne!(da.dt_s, dd.dt_s);
    }

    #[test]
    fn preemptible_draws_mix_both_processes() {
        let p = plan();
        let mut rng = p.gpu_rng(2, 0);
        let (mut crashes, mut preempts) = (0u32, 0u32);
        for _ in 0..200 {
            let d = p.draw(&mut rng, true).expect("both processes active");
            if d.preemption {
                preempts += 1;
                assert_eq!(d.mttr_s, 20.0);
            } else {
                crashes += 1;
                assert_eq!(d.mttr_s, 5.0);
            }
        }
        // rate split is 2:1 crash:preempt; both must appear.
        assert!(crashes > preempts && preempts > 20, "{crashes}/{preempts}");
    }

    #[test]
    fn pool_projection_filters_by_tier_and_preemptibility() {
        let p = plan();
        let pool1 = p.pool(1, false).expect("tier 1 has faults");
        assert_eq!(pool1.outages().len(), 1);
        let pool0 = p.pool(0, false).expect("replica faults apply");
        assert!(pool0.outages().is_empty());
        // Pool streams match the fleet-level streams for the same tier.
        let mut fleet_rng = p.gpu_rng(1, 5);
        let mut pool_rng = pool1.gpu_rng(5);
        assert_eq!(
            p.draw(&mut fleet_rng, false),
            pool1.draw(&mut pool_rng),
            "pool projection must preserve per-GPU streams"
        );
        // Non-preemptible projection strips the spot process.
        let no_spot = p.pool(0, false).unwrap();
        let mut r = no_spot.gpu_rng(0);
        assert!(!no_spot.draw(&mut r).unwrap().preemption);
    }
}
