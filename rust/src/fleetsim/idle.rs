//! Intrusive idle-GPU tracking (§Perf).
//!
//! Both DES event loops wake a GPU on every arrival. The original code
//! scanned every GPU (`filter(!iterating).max_by_key(free_slots)`) — an
//! O(n_gpus) walk per arrival, which at 512 GPUs and millions of requests
//! dominates the event loop. But the scan's answer is fully determined by
//! a loop invariant: *a GPU is not iterating if and only if it holds zero
//! busy slots* (an iteration is scheduled whenever work is admitted, and
//! only an empty completion clears the flag). Every wake candidate
//! therefore ties at `free_slots == n_slots`, so the `max_by_key` scan
//! reduces to "pick the extreme-index idle GPU" — `max_by_key` keeps the
//! last maximum (highest index, `fleetsim::sim`), the autoscale DES's
//! manual strict-`>` loop keeps the first (lowest index). [`IdleSet`]
//! maintains that set as a bitset: O(1) insert/remove, O(n/64) min/max,
//! and idempotent updates so callers can re-sync membership after any
//! state change without tracking transitions. The DES equivalence tests
//! (`tests/des_engine.rs`) pin the replacement to the scan's output.

/// A set of GPU indices backed by a bitset.
#[derive(Clone, Debug, Default)]
pub struct IdleSet {
    words: Vec<u64>,
}

impl IdleSet {
    pub fn new() -> Self {
        IdleSet { words: Vec::new() }
    }

    /// Clear and resize for `n` indices, all initially absent.
    pub fn reset(&mut self, n: usize) {
        self.words.clear();
        self.words.resize(n.div_ceil(64), 0);
    }

    /// Grow capacity to hold index `n - 1` (existing members kept).
    pub fn grow(&mut self, n: usize) {
        let need = n.div_ceil(64);
        if need > self.words.len() {
            self.words.resize(need, 0);
        }
    }

    pub fn insert(&mut self, i: usize) {
        self.grow(i + 1);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    pub fn remove(&mut self, i: usize) {
        if i >> 6 < self.words.len() {
            self.words[i >> 6] &= !(1u64 << (i & 63));
        }
    }

    pub fn contains(&self, i: usize) -> bool {
        i >> 6 < self.words.len() && self.words[i >> 6] & (1u64 << (i & 63)) != 0
    }

    /// Set membership of `i` in one idempotent call.
    pub fn set(&mut self, i: usize, member: bool) {
        if member {
            self.insert(i);
        } else {
            self.remove(i);
        }
    }

    /// Largest member, if any.
    pub fn max(&self) -> Option<usize> {
        for (wi, &w) in self.words.iter().enumerate().rev() {
            if w != 0 {
                return Some((wi << 6) + 63 - w.leading_zeros() as usize);
            }
        }
        None
    }

    /// Smallest member, if any.
    pub fn min(&self) -> Option<usize> {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != 0 {
                return Some((wi << 6) + w.trailing_zeros() as usize);
            }
        }
        None
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_min_max() {
        let mut s = IdleSet::new();
        s.reset(200);
        assert!(s.is_empty());
        assert_eq!(s.max(), None);
        assert_eq!(s.min(), None);
        s.insert(3);
        s.insert(130);
        s.insert(64);
        assert_eq!(s.min(), Some(3));
        assert_eq!(s.max(), Some(130));
        assert!(s.contains(64));
        s.remove(130);
        assert_eq!(s.max(), Some(64));
        s.remove(3);
        s.remove(64);
        assert!(s.is_empty());
    }

    #[test]
    fn set_is_idempotent() {
        let mut s = IdleSet::new();
        s.reset(10);
        s.set(5, true);
        s.set(5, true);
        assert_eq!(s.min(), Some(5));
        s.set(5, false);
        s.set(5, false);
        assert!(s.is_empty());
    }

    #[test]
    fn grows_on_demand() {
        let mut s = IdleSet::new();
        s.reset(2);
        s.insert(1000);
        assert_eq!(s.max(), Some(1000));
        s.remove(5000); // out of range: no-op, no panic
        assert_eq!(s.max(), Some(1000));
        assert!(!s.contains(5000));
    }

    #[test]
    fn matches_a_reference_scan() {
        // Pseudo-random insert/remove stream vs a Vec<bool> reference.
        let mut s = IdleSet::new();
        s.reset(150);
        let mut reference = vec![false; 150];
        let mut x = 0x9E3779B97F4A7C15u64;
        for _ in 0..10_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let i = (x >> 33) as usize % 150;
            let member = x & 1 == 0;
            s.set(i, member);
            reference[i] = member;
            let want_max = reference.iter().rposition(|&b| b);
            let want_min = reference.iter().position(|&b| b);
            assert_eq!(s.max(), want_max);
            assert_eq!(s.min(), want_min);
        }
    }
}
