//! Fleet-level simulation: route a workload trace through the pool
//! boundary (with optional C&R) and simulate both pools (Table 5).

use crate::config::GpuProfile;
use crate::fleetsim::sim::{simulate_pool, SimConfig, SimRequest, SimResult};
use crate::planner::Plan;
use crate::util::rng::Rng;
use crate::workload::arrivals::PoissonArrivals;
use crate::workload::traces::Workload;

/// Where a simulated request ended up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Short,
    /// Compressed into the short pool (C&R).
    ShortCompressed,
    Long,
}

/// Routed per-pool traces plus bookkeeping.
#[derive(Debug)]
pub struct RoutedTrace {
    pub short: Vec<SimRequest>,
    pub long: Vec<SimRequest>,
    pub n_compressed: u64,
    pub n_total: u64,
}

/// Sample `n` requests at rate `lambda` and route them at boundary
/// `b_short` with compression bandwidth `gamma` and compressibility `p_c`
/// (the DES-side mirror of Eq. 1-2). Compressed requests enter the short
/// pool at exactly `L_in = B - L_out` (Eq. 15).
pub fn route_trace(
    w: &Workload,
    lambda: f64,
    n: usize,
    b_short: u32,
    gamma: f64,
    seed: u64,
) -> RoutedTrace {
    let mut rng = Rng::new(seed ^ 0xF1EE7);
    let arrivals = PoissonArrivals::new(lambda, seed);
    let mut short = Vec::new();
    let mut long = Vec::new();
    let mut n_compressed = 0u64;
    for (i, t) in arrivals.take(n).enumerate() {
        let r = w.sample_request(i as u64, t, &mut rng);
        let band_hi = crate::compress::gate::band_hi(b_short, gamma);
        if r.l_total <= b_short {
            short.push(SimRequest {
                arrival_s: t,
                l_in: r.l_in,
                l_out: r.l_out,
            });
        } else if r.l_total <= band_hi
            && r.category.compressible()
            && r.l_out < b_short
        {
            // C&R: compressed to the Eq. 15 budget.
            n_compressed += 1;
            short.push(SimRequest {
                arrival_s: t,
                l_in: b_short - r.l_out,
                l_out: r.l_out,
            });
        } else {
            long.push(SimRequest {
                arrival_s: t,
                l_in: r.l_in,
                l_out: r.l_out,
            });
        }
    }
    RoutedTrace {
        short,
        long,
        n_compressed,
        n_total: n as u64,
    }
}

/// Per-pool DES results for a provisioned fleet.
#[derive(Debug)]
pub struct FleetSimResult {
    pub short: Option<SimResult>,
    pub long: Option<SimResult>,
    pub routed: RoutedTrace,
}

/// Simulate a planned fleet against a freshly sampled trace of `n`
/// requests (paper §7.4: 30,000 per pool).
pub fn simulate_fleet(
    w: &Workload,
    plan: &Plan,
    g: &GpuProfile,
    lambda: f64,
    n: usize,
    seed: u64,
) -> FleetSimResult {
    let routed = route_trace(w, lambda, n, plan.b_short, plan.gamma, seed);
    // Open the utilization window only after ~3 mean slot occupancies: an
    // empty pool with E[S] in the tens of seconds needs that long to fill
    // to steady state, and counting the fill-up biases rho-hat low.
    let warm = |svc: &Option<crate::queueing::service::ServiceStats>| {
        svc.as_ref().map(|s| 3.0 * s.e_s).unwrap_or(0.0)
    };
    // The two pools' traces are disjoint and their simulations independent,
    // so they run on scoped threads (§Perf: halves Table-5 wall time);
    // per-pool results are bit-identical to the sequential run.
    let (short, long) = std::thread::scope(|scope| {
        let hs = (plan.short.n_gpus > 0 && !routed.short.is_empty()).then(|| {
            scope.spawn(|| {
                let mut cfg =
                    SimConfig::new(g.clone(), plan.short.n_gpus, g.n_max(plan.b_short));
                cfg.warmup_s = warm(&plan.short.svc);
                simulate_pool(&cfg, &routed.short)
            })
        });
        let hl = (plan.long.n_gpus > 0 && !routed.long.is_empty()).then(|| {
            scope.spawn(|| {
                let mut cfg = SimConfig::new(g.clone(), plan.long.n_gpus, g.n_max_long());
                cfg.warmup_s = warm(&plan.long.svc);
                simulate_pool(&cfg, &routed.long)
            })
        });
        (
            hs.map(|h| h.join().expect("short-pool DES panicked")),
            hl.map(|h| h.join().expect("long-pool DES panicked")),
        )
    });
    FleetSimResult {
        short,
        long,
        routed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traces;

    #[test]
    fn routing_fractions_match_alpha_beta() {
        let w = traces::azure();
        let routed = route_trace(&w, 1000.0, 50_000, 4096, 1.5, 1);
        let short_frac = routed.short.len() as f64 / 50_000.0;
        // alpha' = alpha + beta * p_c = 0.898 + 0.078 (p_c = 1 for Azure).
        assert!((short_frac - 0.976).abs() < 0.01, "short frac {short_frac}");
        let comp_frac = routed.n_compressed as f64 / 50_000.0;
        assert!((comp_frac - 0.078).abs() < 0.01, "compressed frac {comp_frac}");
    }

    #[test]
    fn gamma_one_disables_compression() {
        let w = traces::azure();
        let routed = route_trace(&w, 1000.0, 20_000, 4096, 1.0, 2);
        assert_eq!(routed.n_compressed, 0);
    }

    #[test]
    fn agent_code_reduces_pc() {
        // Agent-heavy: ~25% of borderline traffic is code -> compressed
        // fraction ~ beta * 0.75.
        let w = traces::agent_heavy();
        let routed = route_trace(&w, 1000.0, 50_000, 8192, 1.5, 3);
        let comp_frac = routed.n_compressed as f64 / 50_000.0;
        assert!(
            (comp_frac - 0.112 * 0.75).abs() < 0.01,
            "compressed frac {comp_frac}"
        );
    }

    #[test]
    fn compressed_requests_fit_boundary() {
        let w = traces::azure();
        let routed = route_trace(&w, 500.0, 20_000, 4096, 1.5, 4);
        for r in &routed.short {
            assert!(r.l_in + r.l_out <= 4096, "short-pool overflow: {r:?}");
        }
    }

    #[test]
    fn conservation() {
        let w = traces::lmsys();
        let routed = route_trace(&w, 800.0, 10_000, 1536, 1.5, 5);
        assert_eq!(routed.short.len() + routed.long.len(), 10_000);
    }
}
