//! Fleet-level simulation: route a workload trace through the K−1 tier
//! boundaries (with optional per-boundary C&R) and simulate every tier
//! (Table 5 / Table 8). The paper's two-pool fleet is the K = 2 special
//! case: [`route_trace`] and [`simulate_fleet`] are thin projections of
//! the tiered path and reproduce the pre-refactor outputs bit-for-bit
//! (`tests/tier_equivalence.rs`).

use crate::config::GpuProfile;
use crate::fleetsim::faults::{FaultPlan, PoolFaultPlan};
use crate::fleetsim::sim::{simulate_pool, SimConfig, SimRequest, SimResult};
use crate::planner::{Plan, TieredPlan};
use crate::util::rng::Rng;
use crate::workload::arrivals::{
    ArrivalProcess, NonstationaryArrivals, PoissonArrivals, RateModel,
};
use crate::workload::traces::Workload;

/// Where a simulated request ended up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    Short,
    /// Compressed into the short pool (C&R).
    ShortCompressed,
    Long,
}

/// Routed per-pool traces plus bookkeeping (two-pool view).
#[derive(Debug)]
pub struct RoutedTrace {
    pub short: Vec<SimRequest>,
    pub long: Vec<SimRequest>,
    pub n_compressed: u64,
    pub n_total: u64,
}

/// Routed per-tier traces plus bookkeeping (K-tier view). `tiers[i]`
/// holds the requests that landed in tier `i`, post-compression.
#[derive(Debug)]
pub struct TieredTrace {
    pub tiers: Vec<Vec<SimRequest>>,
    /// Compressions per boundary (requests squeezed down into tier `i`).
    pub n_compressed_at: Vec<u64>,
    pub n_total: u64,
}

impl TieredTrace {
    pub fn n_compressed(&self) -> u64 {
        self.n_compressed_at.iter().sum()
    }
}

/// Sample `n` requests at rate `lambda` and route them across the tier
/// `boundaries` with per-boundary compression bandwidths `gammas` (the
/// DES-side mirror of Eq. 1-2, per boundary). The first tier whose
/// boundary fits the request takes it; a compressible request inside a
/// boundary's band `(B_i, gamma_i B_i]` is compressed down into tier `i`
/// at exactly `L_in = B_i - L_out` (Eq. 15); everything else falls
/// through to the last tier.
pub fn route_trace_tiered(
    w: &Workload,
    lambda: f64,
    n: usize,
    boundaries: &[u32],
    gammas: &[f64],
    seed: u64,
) -> TieredTrace {
    let mut arrivals = PoissonArrivals::new(lambda, seed);
    route_trace_stream(w, &mut arrivals, n, boundaries, gammas, seed)
}

/// [`route_trace_tiered`] over an arbitrary (possibly nonstationary)
/// arrival model — the stress archetype's and Table 9's trace source. The
/// request-body RNG is seeded exactly as the stationary router seeds it,
/// so a constant-rate model reproduces `route_trace_tiered` bit-for-bit
/// (constant-rate `NonstationaryArrivals` are bitwise Poisson — tested in
/// `tests/autoscale_control.rs`).
pub fn route_trace_tiered_model(
    w: &Workload,
    model: &RateModel,
    n: usize,
    boundaries: &[u32],
    gammas: &[f64],
    seed: u64,
) -> TieredTrace {
    let mut arrivals = NonstationaryArrivals::new(model.clone(), seed);
    route_trace_stream(w, &mut arrivals, n, boundaries, gammas, seed)
}

/// The shared routing core: draw `n` requests off `arrivals` and ladder
/// each across the tier boundaries (per-boundary C&R, Eq. 15).
fn route_trace_stream(
    w: &Workload,
    arrivals: &mut dyn ArrivalProcess,
    n: usize,
    boundaries: &[u32],
    gammas: &[f64],
    seed: u64,
) -> TieredTrace {
    assert!(!boundaries.is_empty(), "need at least one boundary");
    assert_eq!(boundaries.len(), gammas.len());
    let k = boundaries.len() + 1;
    let mut rng = Rng::new(seed ^ 0xF1EE7);
    let mut tiers: Vec<Vec<SimRequest>> = (0..k).map(|_| Vec::new()).collect();
    let mut n_compressed_at = vec![0u64; k - 1];
    for i in 0..n {
        let t = arrivals.next_arrival();
        let r = w.sample_request(i as u64, t, &mut rng);
        let (tier, l_in, compressed) = route_request(
            r.l_total,
            r.l_in,
            r.l_out,
            r.category.compressible(),
            boundaries,
            gammas,
        );
        if compressed {
            n_compressed_at[tier] += 1;
        }
        tiers[tier].push(SimRequest {
            arrival_s: t,
            l_in,
            l_out: r.l_out,
        });
    }
    TieredTrace {
        tiers,
        n_compressed_at,
        n_total: n as u64,
    }
}

/// The per-request tier decision shared by [`route_trace_tiered`] and the
/// autoscaling DES (`fleetsim::autoscale`): first tier whose boundary fits
/// takes the request; a compressible request inside a boundary's clamped
/// band `(B_i, gamma_i B_i]` with `L_out < B_i` compresses down into tier
/// `i` at the Eq. 15 budget `L_in = B_i - L_out`; everything else falls
/// through to the last tier. One definition keeps the DES router and the
/// control loop deciding identically (the gateway mirrors the same ladder
/// over estimated lengths). Returns `(tier, post-compression L_in,
/// compressed?)`.
pub fn route_request(
    l_total: u32,
    l_in: u32,
    l_out: u32,
    compressible: bool,
    boundaries: &[u32],
    gammas: &[f64],
) -> (usize, u32, bool) {
    for (tier, (&b, &gamma)) in boundaries.iter().zip(gammas).enumerate() {
        // Clamp the band at the next boundary up, exactly as the planner
        // and gateway do (no-op for already-clamped plan gammas and for
        // the last boundary — K = 2 is untouched).
        let gamma =
            crate::compress::gate::clamp_gamma(b, boundaries.get(tier + 1).copied(), gamma);
        let band_hi = crate::compress::gate::band_hi(b, gamma);
        if l_total <= b {
            return (tier, l_in, false);
        }
        if l_total <= band_hi && compressible && l_out < b {
            // C&R: compressed to the Eq. 15 budget of this boundary.
            return (tier, b - l_out, true);
        }
    }
    (boundaries.len(), l_in, false)
}

/// Two-pool [`route_trace_tiered`] (the paper's evaluation shape).
pub fn route_trace(
    w: &Workload,
    lambda: f64,
    n: usize,
    b_short: u32,
    gamma: f64,
    seed: u64,
) -> RoutedTrace {
    let mut t = route_trace_tiered(w, lambda, n, &[b_short], &[gamma], seed);
    let long = t.tiers.pop().expect("long tier");
    let short = t.tiers.pop().expect("short tier");
    RoutedTrace {
        short,
        long,
        n_compressed: t.n_compressed_at[0],
        n_total: t.n_total,
    }
}

/// Per-pool DES results for a provisioned two-pool fleet.
#[derive(Debug)]
pub struct FleetSimResult {
    pub short: Option<SimResult>,
    pub long: Option<SimResult>,
    pub routed: RoutedTrace,
}

/// Per-tier DES results for a provisioned K-tier fleet.
#[derive(Debug)]
pub struct TieredSimResult {
    pub tiers: Vec<Option<SimResult>>,
    /// Requests per tier that were routed but never simulated to
    /// completion: a tier with traffic but zero provisioned GPUs is
    /// skipped (`tiers[i] = None`), and a horizon-truncated pool reports
    /// its own in-flight remainder. Previously these vanished from the
    /// percentile population silently.
    pub censored: Vec<u64>,
    pub routed: TieredTrace,
}

impl TieredSimResult {
    pub fn censored_total(&self) -> u64 {
        self.censored.iter().sum()
    }
}

/// One tier's DES shape: GPU count, slots per GPU, the warm-up before
/// the utilization window opens, the tier SKU's service-rate multiplier
/// against the shared base profile, and any failure processes projected
/// onto this tier (chaos runs only).
struct TierSimCfg {
    n_gpus: u64,
    n_slots: u32,
    warmup_s: f64,
    mu_scale: f64,
    faults: Option<PoolFaultPlan>,
    /// Per-GPU KV token cap for this tier (`None` = no KV bookkeeping —
    /// the bit-identical slot-only engine).
    kv_cap: Option<u64>,
}

/// Simulate every tier of a routed trace, one capped worker per tier via
/// the shared [`crate::util::par`] substrate (§Perf): the tiers' traces
/// are disjoint and their simulations independent, so per-tier results
/// are bit-identical to a sequential run. Tiers with no GPUs or no
/// traffic are skipped (`None`).
fn simulate_tiers(
    g: &GpuProfile,
    cfgs: &[TierSimCfg],
    traces: &[Vec<SimRequest>],
) -> Vec<Option<SimResult>> {
    assert_eq!(cfgs.len(), traces.len());
    let items: Vec<(&TierSimCfg, &Vec<SimRequest>)> = cfgs.iter().zip(traces).collect();
    crate::util::par::par_map_each(&items, |&(tc, trace)| {
        (tc.n_gpus > 0 && !trace.is_empty()).then(|| {
            // A SKU tier sees the base profile uniformly time-dilated;
            // `scaled_mu(1.0)` clones unchanged, so single-SKU fleets
            // simulate bit-identically to the pre-catalog DES.
            let tier_g = g.scaled_mu(tc.mu_scale);
            let mut cfg = SimConfig::new(tier_g, tc.n_gpus, tc.n_slots);
            cfg.warmup_s = tc.warmup_s;
            cfg.faults = tc.faults.clone();
            cfg.kv_cap_tokens = tc.kv_cap;
            simulate_pool(&cfg, trace)
        })
    })
}

/// Warm-up before the utilization window opens: ~3 mean slot occupancies —
/// an empty pool with E[S] in the tens of seconds needs that long to fill
/// to steady state, and counting the fill-up biases rho-hat low.
fn warmup_s(svc: &Option<crate::queueing::service::ServiceStats>) -> f64 {
    svc.as_ref().map(|s| 3.0 * s.e_s).unwrap_or(0.0)
}

/// Simulate a planned two-pool fleet against a freshly sampled trace of
/// `n` requests (paper §7.4: 30,000 per pool). The K = 2 projection of
/// [`simulate_fleet_tiered`].
pub fn simulate_fleet(
    w: &Workload,
    plan: &Plan,
    g: &GpuProfile,
    lambda: f64,
    n: usize,
    seed: u64,
) -> FleetSimResult {
    let cfgs = [
        TierSimCfg {
            n_gpus: plan.short.n_gpus,
            n_slots: g.n_max(plan.b_short),
            warmup_s: warmup_s(&plan.short.svc),
            mu_scale: 1.0,
            faults: None,
            kv_cap: None,
        },
        TierSimCfg {
            n_gpus: plan.long.n_gpus,
            n_slots: g.n_max_long(),
            warmup_s: warmup_s(&plan.long.svc),
            mu_scale: 1.0,
            faults: None,
            kv_cap: None,
        },
    ];
    let mut routed = route_trace_tiered(w, lambda, n, &[plan.b_short], &[plan.gamma], seed);
    let mut results = simulate_tiers(g, &cfgs, &routed.tiers);
    let long = results.pop().expect("long result");
    let short = results.pop().expect("short result");
    let long_trace = routed.tiers.pop().expect("long trace");
    let short_trace = routed.tiers.pop().expect("short trace");
    FleetSimResult {
        short,
        long,
        routed: RoutedTrace {
            short: short_trace,
            long: long_trace,
            n_compressed: routed.n_compressed_at[0],
            n_total: routed.n_total,
        },
    }
}

/// Simulate a planned K-tier fleet against a freshly sampled trace of `n`
/// requests: route across every boundary, then run one DES per tier on
/// scoped threads. Slot counts and SKU rate multipliers come from the
/// plan's [`FleetSpec`] (`crate::config::FleetSpec`); `g` supplies the
/// base iteration-latency model, per-tier time-dilated by each recorded
/// SKU choice (identity for plain single-SKU plans).
pub fn simulate_fleet_tiered(
    w: &Workload,
    plan: &TieredPlan,
    g: &GpuProfile,
    lambda: f64,
    n: usize,
    seed: u64,
) -> TieredSimResult {
    simulate_fleet_tiered_chaos(w, plan, g, lambda, n, seed, &FaultPlan::default())
}

/// [`simulate_fleet_tiered`] with failure injection: `faults` is
/// projected onto each tier ([`FaultPlan::pool`]), so a tier nothing in
/// the plan touches runs the verbatim fault-free path. The default
/// (empty) plan projects to `None` everywhere — bit-identical to
/// `simulate_fleet_tiered`, which delegates here.
pub fn simulate_fleet_tiered_chaos(
    w: &Workload,
    plan: &TieredPlan,
    g: &GpuProfile,
    lambda: f64,
    n: usize,
    seed: u64,
    faults: &FaultPlan,
) -> TieredSimResult {
    simulate_fleet_tiered_kv(w, plan, g, lambda, n, seed, faults, None)
}

/// [`simulate_fleet_tiered_chaos`] with per-tier KV caps: `kv` is the
/// fraction of each tier's `n_max * c_max` token budget available to
/// request KV ([`crate::queueing::kv::KvPlanPolicy`]). `None` performs no
/// KV bookkeeping — bit-identical to the slot-only engines, which is why
/// the chaos/plain entry points delegate here with `None`.
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet_tiered_kv(
    w: &Workload,
    plan: &TieredPlan,
    g: &GpuProfile,
    lambda: f64,
    n: usize,
    seed: u64,
    faults: &FaultPlan,
    kv: Option<crate::queueing::kv::KvPlanPolicy>,
) -> TieredSimResult {
    let boundaries = plan.boundaries();
    let routed = route_trace_tiered(w, lambda, n, &boundaries, &plan.gammas, seed);
    let cfgs: Vec<TierSimCfg> = plan
        .tiers
        .iter()
        .zip(&plan.spec.tiers)
        .enumerate()
        .map(|(ti, (pool, tier))| TierSimCfg {
            n_gpus: pool.n_gpus,
            n_slots: tier.n_max,
            warmup_s: warmup_s(&pool.svc),
            // Mixed-SKU plans record each tier's rate multiplier on the
            // spec; plain plans default to 1.0 (identity profile).
            mu_scale: tier.mu_scale(),
            faults: faults.pool(ti, tier.sku.is_some_and(|s| s.preemptible)),
            kv_cap: kv.map(|p| p.cap_tokens(tier.n_max, tier.c_max)),
        })
        .collect();
    let results = simulate_tiers(g, &cfgs, &routed.tiers);
    let censored: Vec<u64> = results
        .iter()
        .zip(&routed.tiers)
        .map(|(res, trace)| match res {
            Some(r) => r.censored,
            // Routed traffic on an unprovisioned tier is censored in
            // full, not silently dropped.
            None => trace.len() as u64,
        })
        .collect();
    TieredSimResult {
        tiers: results,
        censored,
        routed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::traces;

    #[test]
    fn routing_fractions_match_alpha_beta() {
        let w = traces::azure();
        let routed = route_trace(&w, 1000.0, 50_000, 4096, 1.5, 1);
        let short_frac = routed.short.len() as f64 / 50_000.0;
        // alpha' = alpha + beta * p_c = 0.898 + 0.078 (p_c = 1 for Azure).
        assert!((short_frac - 0.976).abs() < 0.01, "short frac {short_frac}");
        let comp_frac = routed.n_compressed as f64 / 50_000.0;
        assert!((comp_frac - 0.078).abs() < 0.01, "compressed frac {comp_frac}");
    }

    #[test]
    fn gamma_one_disables_compression() {
        let w = traces::azure();
        let routed = route_trace(&w, 1000.0, 20_000, 4096, 1.0, 2);
        assert_eq!(routed.n_compressed, 0);
    }

    #[test]
    fn agent_code_reduces_pc() {
        // Agent-heavy: ~25% of borderline traffic is code -> compressed
        // fraction ~ beta * 0.75.
        let w = traces::agent_heavy();
        let routed = route_trace(&w, 1000.0, 50_000, 8192, 1.5, 3);
        let comp_frac = routed.n_compressed as f64 / 50_000.0;
        assert!(
            (comp_frac - 0.112 * 0.75).abs() < 0.01,
            "compressed frac {comp_frac}"
        );
    }

    #[test]
    fn compressed_requests_fit_boundary() {
        let w = traces::azure();
        let routed = route_trace(&w, 500.0, 20_000, 4096, 1.5, 4);
        for r in &routed.short {
            assert!(r.l_in + r.l_out <= 4096, "short-pool overflow: {r:?}");
        }
    }

    #[test]
    fn conservation() {
        let w = traces::lmsys();
        let routed = route_trace(&w, 800.0, 10_000, 1536, 1.5, 5);
        assert_eq!(routed.short.len() + routed.long.len(), 10_000);
    }

    #[test]
    fn three_tier_conservation_and_no_overflow() {
        let w = traces::agent_heavy();
        let boundaries = [4096u32, 16_384];
        let t = route_trace_tiered(&w, 1000.0, 30_000, &boundaries, &[1.5, 1.5], 6);
        assert_eq!(t.tiers.len(), 3);
        let total: usize = t.tiers.iter().map(Vec::len).sum();
        assert_eq!(total, 30_000);
        // No request may exceed its tier's window (the KV-overflow
        // guarantee, per tier).
        for (tier, &b) in boundaries.iter().enumerate() {
            for r in &t.tiers[tier] {
                assert!(r.l_in + r.l_out <= b, "tier {tier} overflow: {r:?}");
            }
        }
        // With two open bands, both boundaries see compressions on this
        // fat-tailed trace.
        assert!(t.n_compressed_at[0] > 0 && t.n_compressed_at[1] > 0);
        assert_eq!(t.n_compressed(), t.n_compressed_at[0] + t.n_compressed_at[1]);
    }

    #[test]
    fn model_router_constant_rate_is_bitwise_stationary() {
        // The stress/nonstationary routing front-end must reproduce the
        // stationary router exactly for a constant-rate model.
        let w = traces::azure();
        let boundaries = [4096u32];
        let gammas = [1.5];
        let a = route_trace_tiered(&w, 750.0, 12_000, &boundaries, &gammas, 31);
        let model = crate::workload::arrivals::RateModel::Constant(750.0);
        let b = route_trace_tiered_model(&w, &model, 12_000, &boundaries, &gammas, 31);
        assert_eq!(a.n_compressed_at, b.n_compressed_at);
        for (ta, tb) in a.tiers.iter().zip(&b.tiers) {
            assert_eq!(ta.len(), tb.len());
            for (ra, rb) in ta.iter().zip(tb) {
                assert_eq!(ra.arrival_s.to_bits(), rb.arrival_s.to_bits());
                assert_eq!(ra.l_in, rb.l_in);
                assert_eq!(ra.l_out, rb.l_out);
            }
        }
    }

    #[test]
    fn tiered_k2_matches_route_trace() {
        let w = traces::azure();
        let two = route_trace(&w, 700.0, 15_000, 4096, 1.5, 9);
        let tiered = route_trace_tiered(&w, 700.0, 15_000, &[4096], &[1.5], 9);
        assert_eq!(two.short.len(), tiered.tiers[0].len());
        assert_eq!(two.long.len(), tiered.tiers[1].len());
        assert_eq!(two.n_compressed, tiered.n_compressed_at[0]);
    }
}
