//! `inference-fleet-sim` (paper §7.4): a deterministic discrete-event
//! simulator for heterogeneous multi-pool LLM fleets, used to validate the
//! analytical model's utilization predictions within 3% — plus the
//! autoscaling variant ([`autoscale`]) that drives a K-tier fleet through
//! nonstationary arrivals with a replanning controller in the loop, and
//! the million-scale [`stress`] archetype the overhauled engine (calendar
//! queue, allocation-free loop — see [`events`], [`idle`]) is gated on.
//! Heterogeneous-SKU plans simulate with each tier's GPU timing dilated by
//! its SKU's rate multiplier ([`fleet::simulate_fleet_tiered`]), so the
//! Table-10 mixed fleets are validated by the same DES as the single-SKU
//! ones (bit-identical at `mu_scale = 1`).

pub mod autoscale;
pub mod events;
pub mod faults;
pub mod fleet;
pub mod idle;
pub mod sim;
pub mod stress;

pub use autoscale::{
    simulate_autoscale, simulate_autoscale_chaos, simulate_autoscale_kv, AutoscaleConfig,
    AutoscaleReport, ChaosOpts, KvFleetOpts,
};
pub use events::{EventQueue, PastScheduleError, QueueImpl};
pub use faults::{FailureDraw, FaultPlan, PoolFaultPlan, ReplicaFaults, SpotFaults, TierOutage};
pub use fleet::{
    route_request, route_trace, route_trace_tiered, route_trace_tiered_model, simulate_fleet,
    simulate_fleet_tiered, simulate_fleet_tiered_chaos, simulate_fleet_tiered_kv, FleetSimResult,
    RoutedTrace, TieredSimResult, TieredTrace,
};
pub use sim::{simulate_pool, simulate_pool_with, SimConfig, SimRequest, SimResult, SimScratch};
pub use stress::{mean_occupancy_s, run_stress, StressConfig, StressReport};
