//! `inference-fleet-sim` (paper §7.4): a deterministic discrete-event
//! simulator for heterogeneous multi-pool LLM fleets, used to validate the
//! analytical model's utilization predictions within 3%.

pub mod events;
pub mod fleet;
pub mod sim;

pub use fleet::{
    route_trace, route_trace_tiered, simulate_fleet, simulate_fleet_tiered, FleetSimResult,
    RoutedTrace, TieredSimResult, TieredTrace,
};
pub use sim::{simulate_pool, SimConfig, SimRequest, SimResult};
