//! `inference-fleet-sim` (paper §7.4): a deterministic discrete-event
//! simulator for heterogeneous multi-pool LLM fleets, used to validate the
//! analytical model's utilization predictions within 3%.

pub mod events;
pub mod fleet;
pub mod sim;

pub use fleet::{route_trace, simulate_fleet, FleetSimResult, RoutedTrace};
pub use sim::{simulate_pool, SimConfig, SimRequest, SimResult};
