//! `inference-fleet-sim` (paper §7.4): a deterministic discrete-event
//! simulator for heterogeneous multi-pool LLM fleets, used to validate the
//! analytical model's utilization predictions within 3% — plus the
//! autoscaling variant ([`autoscale`]) that drives a K-tier fleet through
//! nonstationary arrivals with a replanning controller in the loop.

pub mod autoscale;
pub mod events;
pub mod fleet;
pub mod sim;

pub use autoscale::{simulate_autoscale, AutoscaleConfig, AutoscaleReport};
pub use fleet::{
    route_request, route_trace, route_trace_tiered, simulate_fleet, simulate_fleet_tiered,
    FleetSimResult, RoutedTrace, TieredSimResult, TieredTrace,
};
pub use sim::{simulate_pool, SimConfig, SimRequest, SimResult};
