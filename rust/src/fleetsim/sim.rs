//! inference-fleet-sim: discrete-event simulation of one pool under
//! continuous batching (paper §7.4's validation substrate).
//!
//! Model: `n_gpus` GPUs, each with `n_slots` KV slots advancing in lockstep
//! iterations of `t_iter` (Eq. 3). A request occupies one slot for
//! `ceil(L_in / C_chunk) + L_out` iterations (Eq. 4); its first token
//! appears after the prefill iterations plus one decode step (Eq. 7).
//! Requests queue FCFS per pool; GPUs admit from the shared queue at
//! iteration boundaries (and idle GPUs wake on arrival). Utilization is
//! busy-slot-time over provisioned slot-time inside a measurement window
//! that excludes warm-up and drain — the quantity Table 5 compares against
//! the analytical rho.
//!
//! §Perf (DES engine overhaul): the event loop is allocation-free in
//! steady state — busy slots live in a dense per-GPU slab (`Vec<Active>`
//! with swap-remove; slots are symmetric, so only the multiset of active
//! requests is observable), idle GPUs are tracked in an intrusive bitset
//! ([`IdleSet`]) instead of a per-arrival scan, and all per-run state
//! (event-queue buckets, FCFS queue, GPU slabs) can be recycled across
//! runs through [`SimScratch`]. The scheduler defaults to the calendar
//! queue with the binary heap retained as the equivalence oracle
//! ([`QueueImpl`]); results are bit-identical either way, property-tested
//! against the verbatim pre-overhaul simulator in `tests/des_engine.rs`.

use std::collections::VecDeque;

use crate::config::GpuProfile;
use crate::fleetsim::events::{EventQueue, QueueImpl};
use crate::fleetsim::faults::PoolFaultPlan;
use crate::fleetsim::idle::IdleSet;
use crate::util::rng::Rng;
use crate::util::stats::Samples;

/// One simulated request (already routed to this pool; lengths are
/// post-compression values for C&R traffic).
#[derive(Clone, Copy, Debug)]
pub struct SimRequest {
    pub arrival_s: f64,
    pub l_in: u32,
    pub l_out: u32,
}

/// Pool simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub gpu: GpuProfile,
    pub n_gpus: u64,
    /// KV slots per GPU for this pool's context window.
    pub n_slots: u32,
    /// Lockstep iteration latency at the configured slot count (paper §3.1
    /// "all n_max slots advance in lockstep"). When false, t_iter follows
    /// the instantaneous occupancy (Eq. 3 with n = busy slots) — an
    /// ablation mode.
    pub lockstep_full: bool,
    /// Fraction of requests treated as warm-up (excluded from metrics).
    pub warmup_frac: f64,
    /// Additional absolute warm-up time (s) before the utilization window
    /// opens. Pools with long slot occupancies (E[S] tens of seconds) need
    /// several service times to reach steady state; callers that know E[S]
    /// (e.g. the Table-5 validation) set this to ~3x E[S].
    pub warmup_s: f64,
    /// Hard simulation horizon (s). `None` (the default) drains every
    /// request — the pre-existing behaviour, bit-identical. With a
    /// horizon, events past it are discarded and the requests still in
    /// flight or queued are reported in [`SimResult::censored`] instead of
    /// silently vanishing from the latency percentiles.
    pub horizon_s: Option<f64>,
    /// Event-scheduler backend: the calendar queue by default; the binary
    /// heap is the bit-identical oracle (tests, the `des_throughput`
    /// bench's before/after comparison).
    pub queue_impl: QueueImpl,
    /// Failure injection projected onto this pool
    /// ([`crate::fleetsim::faults::FaultPlan::pool`]). `None` (the
    /// default) schedules no fault events at all, so the run is
    /// bit-identical to the pre-chaos simulator. A crash/preemption/outage
    /// kills the victim GPU's in-flight requests — they requeue at the
    /// *head* of the shared FCFS queue — and the GPU rejoins after the
    /// drawn repair time (no provisioning delay at pool level; the
    /// autoscale DES adds one).
    pub faults: Option<PoolFaultPlan>,
    /// Per-GPU KV capacity in tokens. A request reserves `l_in + l_out`
    /// tokens for its whole residency at admission (the engine can never
    /// be forced to evict mid-decode); admission blocks head-of-line when
    /// the reservation would exceed the cap, so requests queue rather
    /// than oversubscribe. `None` (the default) performs no KV
    /// bookkeeping in the admission path — bit-identical to the
    /// slot-only engine (`tests/kv_stability.rs`).
    pub kv_cap_tokens: Option<u64>,
    /// Crash-retry budget per request: a kill beyond this many retries
    /// drops the request into [`SimResult::dropped_retries`] instead of
    /// requeueing it. `None` (the default) retries without bound —
    /// bit-identical to the pre-budget engine.
    pub max_retries: Option<u32>,
}

impl SimConfig {
    pub fn new(gpu: GpuProfile, n_gpus: u64, n_slots: u32) -> Self {
        SimConfig {
            gpu,
            n_gpus,
            n_slots,
            lockstep_full: true,
            warmup_frac: 0.1,
            warmup_s: 0.0,
            horizon_s: None,
            queue_impl: QueueImpl::Calendar,
            faults: None,
            kv_cap_tokens: None,
            max_retries: None,
        }
    }
}

/// Aggregate results for one pool.
#[derive(Debug)]
pub struct SimResult {
    /// Measured GPU utilization rho-hat: busy-slot-time / provisioned
    /// slot-time within the measurement window.
    pub utilization: f64,
    /// TTFT samples (s), measured requests only.
    pub ttft: Samples,
    /// Queue-wait samples (s).
    pub wait: Samples,
    /// Completed requests (all, including warm-up).
    pub completed: u64,
    /// Requests still queued or in flight when the simulation horizon
    /// closed (always 0 without [`SimConfig::horizon_s`] — the run drains).
    /// Censored requests contribute no TTFT/wait samples; reporting them
    /// separately keeps the percentiles honest instead of survivor-biased.
    pub censored: u64,
    /// Measurement window (s).
    pub window: (f64, f64),
    /// Discrete events processed (arrivals + GPU iterations; fault events
    /// are not counted) — the numerator of the `des_throughput` bench's
    /// events/s metric.
    pub events: u64,
    /// Replica crashes that struck this pool (0 with faults off).
    pub crashes: u64,
    /// Spot preemptions that struck this pool (0 with faults off).
    pub preemptions: u64,
    /// In-flight requests killed by a fault and requeued at the queue
    /// head — each kill is exactly one retry, so this doubles as the
    /// pool's retry count (the conservation identity
    /// `tests/chaos_conservation.rs` pins).
    pub killed_in_flight: u64,
    /// Requests whose crash-retry budget ([`SimConfig::max_retries`]) was
    /// exhausted: dropped, never completed. Conservation becomes
    /// `completed + censored + dropped_retries == n`; always 0 with an
    /// unbounded budget.
    pub dropped_retries: u64,
    /// Mean KV occupancy over the measurement window as a fraction of
    /// `n_gpus * kv_cap_tokens` (0.0 with KV tracking off) — the DES
    /// measurement the analytical `rho_kv` is validated against
    /// (Table 12).
    pub kv_util: f64,
    /// Admission attempts blocked by the KV cap while slots were free —
    /// the signature of a KV-bound (rather than slot-bound) pool.
    pub kv_blocked: u64,
    /// Ledger violations (reserved tokens above the cap). Zero by
    /// construction — reservation admission never oversubscribes — and
    /// kept as a tripwire for the CI overload gate.
    pub kv_violations: u64,
}

#[derive(Clone, Copy, Debug)]
struct Active {
    req: usize,
    /// Prefill iterations remaining before the first token.
    prefill_left: u32,
    /// Total iterations remaining (prefill + decode).
    iters_left: u32,
    /// Whether TTFT has been recorded.
    first_token_done: bool,
    /// KV tokens reserved for this request (`l_in + l_out`; 0 with KV
    /// tracking off), released at completion or kill.
    kv_tokens: u32,
}

struct Gpu {
    /// Busy slots, densely packed (slot identity is immaterial — only the
    /// multiset of in-flight requests is observable).
    active: Vec<Active>,
    n_slots: u32,
    /// An iteration-completion event is in flight. Loop invariant:
    /// `!iterating` implies `active.is_empty()` (see `fleetsim::idle`).
    iterating: bool,
    /// Integral of busy slots over time, clipped to the window.
    busy_integral: f64,
    /// KV tokens currently reserved (sum of active `kv_tokens`; always 0
    /// with KV tracking off).
    kv_reserved: u64,
    /// Integral of reserved KV tokens over time, clipped to the window.
    kv_integral: f64,
    last_change: f64,
    /// Crashed / preempted / in an outage: provisioned but not serving.
    down: bool,
    /// Bumped on every kill; events stamped with an older generation are
    /// stale and skipped. Always 0 with faults off.
    gen: u32,
    /// This GPU's seeded failure stream (chaos runs only).
    frng: Option<Rng>,
    /// Repair time / classification of the next drawn failure.
    fail_mttr: f64,
    fail_preempt: bool,
}

impl Gpu {
    fn new(n_slots: u32) -> Self {
        Gpu {
            active: Vec::with_capacity(n_slots as usize),
            n_slots,
            iterating: false,
            busy_integral: 0.0,
            kv_reserved: 0,
            kv_integral: 0.0,
            last_change: 0.0,
            down: false,
            gen: 0,
            frng: None,
            fail_mttr: 0.0,
            fail_preempt: false,
        }
    }

    /// Re-initialize for a new run, keeping the slab's capacity.
    fn reset(&mut self, n_slots: u32) {
        self.active.clear();
        self.active.reserve(n_slots as usize);
        self.n_slots = n_slots;
        self.iterating = false;
        self.busy_integral = 0.0;
        self.kv_reserved = 0;
        self.kv_integral = 0.0;
        self.last_change = 0.0;
        self.down = false;
        self.gen = 0;
        self.frng = None;
        self.fail_mttr = 0.0;
        self.fail_preempt = false;
    }

    fn n_busy(&self) -> u32 {
        self.active.len() as u32
    }

    fn accumulate(&mut self, t: f64, window: (f64, f64)) {
        let lo = self.last_change.max(window.0);
        let hi = t.min(window.1);
        if hi > lo {
            self.busy_integral += self.n_busy() as f64 * (hi - lo);
            // Zero forever with KV tracking off (kv_reserved stays 0).
            self.kv_integral += self.kv_reserved as f64 * (hi - lo);
        }
        self.last_change = t;
    }

    fn free_slots(&self) -> u32 {
        self.n_slots - self.n_busy()
    }
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(usize),
    /// (gpu index, generation) — stale generations (the GPU was killed
    /// after scheduling) are skipped. Always 0 with faults off, so the
    /// fault-free event stream is unchanged payload-for-payload.
    Iteration(usize, u32),
    /// (gpu index, generation) — a drawn crash/preemption strikes.
    Crash(usize, u32),
    /// (gpu index, generation) — repair completes.
    Restore(usize, u32),
    /// A scheduled pool-wide outage window opens / closes.
    OutageStart,
    OutageEnd,
}

/// Recyclable per-run state for [`simulate_pool_with`] (§Perf): event
/// queue buckets, the FCFS queue, GPU slot slabs, and the idle bitset are
/// all reused across runs, so repeated simulations (replications, sweeps,
/// benches) allocate nothing in steady state.
#[derive(Default)]
pub struct SimScratch {
    gpus: Vec<Gpu>,
    queue: VecDeque<usize>,
    events: Option<EventQueue<Ev>>,
    idle: IdleSet,
    /// Per-request kill counts (allocated only under a retry budget).
    retries: Vec<u32>,
}

impl SimScratch {
    pub fn new() -> Self {
        SimScratch::default()
    }
}

/// The per-run KV ledger counters threaded through admission.
#[derive(Clone, Copy, Default)]
struct KvLedger {
    cap: Option<u64>,
    blocked: u64,
    violations: u64,
}

/// FCFS admission: fill `g`'s free slots from the shared queue, recording
/// each admission's queue wait (measured requests only). Under a KV cap
/// the head of line must also fit the GPU's remaining token budget —
/// requests behind it wait (FCFS is preserved; no overtaking).
fn admit(
    g: &mut Gpu,
    queue: &mut VecDeque<usize>,
    t: f64,
    wait: &mut Samples,
    requests: &[SimRequest],
    warm: usize,
    chunk: u32,
    kv: &mut KvLedger,
) {
    while g.free_slots() > 0 {
        let Some(&req) = queue.front() else { break };
        let r = &requests[req];
        let mut kv_tokens = 0u32;
        if let Some(cap) = kv.cap {
            kv_tokens = r.l_in + r.l_out;
            if g.kv_reserved + kv_tokens as u64 > cap {
                kv.blocked += 1;
                break;
            }
            g.kv_reserved += kv_tokens as u64;
            if g.kv_reserved > cap {
                kv.violations += 1;
            }
        }
        queue.pop_front();
        let prefill = (r.l_in as u64).div_ceil(chunk as u64) as u32;
        g.active.push(Active {
            req,
            prefill_left: prefill,
            iters_left: prefill + r.l_out,
            first_token_done: false,
            kv_tokens,
        });
        if req >= warm {
            wait.push(t - r.arrival_s);
        }
    }
}

/// Draw GPU `gi`'s next failure from its seeded stream and schedule the
/// crash, creating the stream on first touch.
fn arm_fault(g: &mut Gpu, events: &mut EventQueue<Ev>, t: f64, gi: usize, fp: &PoolFaultPlan) {
    if g.frng.is_none() {
        g.frng = Some(fp.gpu_rng(gi as u64));
    }
    let rng = g.frng.as_mut().expect("just set");
    let Some(d) = fp.draw(rng) else {
        return;
    };
    g.fail_mttr = d.mttr_s;
    g.fail_preempt = d.preemption;
    events.schedule(t + d.dt_s, Ev::Crash(gi, g.gen));
}

/// Take GPU `gi` down: kill its in-flight requests (requeued at the head
/// of the shared FCFS queue in request order), invalidate its pending
/// events via the generation bump, and drop it from the idle set. Under a
/// retry budget, a kill beyond `max_retries` drops the request instead of
/// requeueing it (counted in `dropped`). Returns the number of kills.
#[allow(clippy::too_many_arguments)]
fn take_down(
    g: &mut Gpu,
    queue: &mut VecDeque<usize>,
    idle: &mut IdleSet,
    gi: usize,
    t: f64,
    window: (f64, f64),
    max_retries: Option<u32>,
    retries: &mut [u32],
    dropped: &mut u64,
) -> u64 {
    g.accumulate(t, window);
    let mut killed: Vec<usize> = g.active.iter().map(|a| a.req).collect();
    g.active.clear();
    g.kv_reserved = 0;
    g.iterating = false;
    g.gen = g.gen.wrapping_add(1);
    g.down = true;
    killed.sort_unstable();
    // push_front in descending request order leaves the queue head at the
    // lowest request index — retried work goes back first-in-line.
    for &req in killed.iter().rev() {
        if let Some(budget) = max_retries {
            retries[req] += 1;
            if retries[req] > budget {
                *dropped += 1;
                continue;
            }
        }
        queue.push_front(req);
    }
    idle.remove(gi);
    killed.len() as u64
}

/// Simulate one pool over a request list (must be arrival-sorted).
pub fn simulate_pool(cfg: &SimConfig, requests: &[SimRequest]) -> SimResult {
    simulate_pool_with(cfg, requests, &mut SimScratch::new())
}

/// [`simulate_pool`] with caller-owned scratch — bit-identical results,
/// allocation-free across calls once the scratch is warm.
pub fn simulate_pool_with(
    cfg: &SimConfig,
    requests: &[SimRequest],
    scratch: &mut SimScratch,
) -> SimResult {
    assert!(cfg.n_gpus > 0 && cfg.n_slots > 0);
    assert!(
        requests.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s),
        "requests must be sorted by arrival"
    );
    let n_req = requests.len();
    let warm = (n_req as f64 * cfg.warmup_frac) as usize;
    // Measurement window: from the warm-th arrival to the last arrival
    // (excludes the drain phase, during which no load is offered).
    let window = if n_req == 0 {
        (0.0, 0.0)
    } else {
        let lo = requests[warm.min(n_req - 1)].arrival_s.max(cfg.warmup_s);
        let hi = requests[n_req - 1].arrival_s;
        (lo.min(hi), hi)
    };

    let chunk = cfg.gpu.chunk;
    let t_iter_full = cfg.gpu.t_iter_s(cfg.n_slots);

    // Recycle the scratch: GPU slabs, FCFS queue, idle bitset, events.
    let n_gpus = cfg.n_gpus as usize;
    for g in scratch.gpus.iter_mut().take(n_gpus) {
        g.reset(cfg.n_slots);
    }
    while scratch.gpus.len() < n_gpus {
        scratch.gpus.push(Gpu::new(cfg.n_slots));
    }
    scratch.gpus.truncate(n_gpus);
    scratch.queue.clear();
    scratch.idle.reset(n_gpus);
    scratch.retries.clear();
    if cfg.max_retries.is_some() {
        scratch.retries.resize(n_req, 0);
    }
    let reuse = matches!(&scratch.events, Some(q) if q.queue_impl() == cfg.queue_impl);
    if reuse {
        scratch.events.as_mut().expect("checked").reset();
    } else {
        scratch.events = Some(EventQueue::with_impl(cfg.queue_impl));
    }
    let SimScratch {
        gpus,
        queue,
        events,
        idle,
        retries,
    } = scratch;
    let events = events.as_mut().expect("just set");
    for gi in 0..n_gpus {
        idle.insert(gi);
    }
    for (i, r) in requests.iter().enumerate() {
        events.schedule(r.arrival_s, Ev::Arrival(i));
    }
    // Chaos wiring: arm every GPU's failure stream and schedule this
    // pool's outage windows. None of this runs with faults off, so the
    // event sequence (and hence every tie-break) is unchanged.
    if let Some(fp) = &cfg.faults {
        for (gi, g) in gpus.iter_mut().enumerate() {
            arm_fault(g, events, 0.0, gi, fp);
        }
        for o in fp.outages() {
            events.schedule(o.start_s, Ev::OutageStart);
            events.schedule(o.start_s + o.duration_s, Ev::OutageEnd);
        }
    }

    let mut ttft = Samples::with_capacity(n_req);
    let mut wait = Samples::with_capacity(n_req);
    let mut completed = 0u64;
    let mut n_events = 0u64;
    let mut crashes = 0u64;
    let mut preemptions = 0u64;
    let mut killed_in_flight = 0u64;
    let mut dropped_retries = 0u64;
    let mut kv = KvLedger {
        cap: cfg.kv_cap_tokens,
        ..KvLedger::default()
    };
    let mut outage_depth = 0u32;

    while let Some((t, ev)) = events.pop() {
        if let Some(h) = cfg.horizon_s {
            if t > h {
                break;
            }
        }
        if completed + dropped_retries == n_req as u64 {
            // All work done: a crash-restore cycle with no traffic left
            // would re-arm forever and never terminate.
            match ev {
                Ev::Crash(..) | Ev::Restore(..) | Ev::OutageStart | Ev::OutageEnd => continue,
                _ => {}
            }
        }
        match ev {
            Ev::Arrival(_) | Ev::Iteration(..) => n_events += 1,
            _ => {}
        }
        match ev {
            Ev::Arrival(i) => {
                queue.push_back(i);
                // Wake an idle GPU. All idle GPUs tie at `n_slots` free
                // slots (a non-iterating GPU is empty — loop invariant),
                // so the original `max_by_key(free_slots)` scan reduces
                // to the highest idle index (last maximum wins).
                if let Some(gi) = idle.max() {
                    let g = &mut gpus[gi];
                    debug_assert!(!g.iterating && g.active.is_empty());
                    g.accumulate(t, window);
                    admit(g, queue, t, &mut wait, requests, warm, chunk, &mut kv);
                    if g.n_busy() > 0 {
                        let dt = if cfg.lockstep_full {
                            t_iter_full
                        } else {
                            cfg.gpu.t_iter_s(g.n_busy())
                        };
                        g.iterating = true;
                        idle.remove(gi);
                        events.schedule(t + dt, Ev::Iteration(gi, g.gen));
                    }
                }
            }
            Ev::Iteration(gi, gen) => {
                let g = &mut gpus[gi];
                if g.gen != gen {
                    // Scheduled against a GPU state a kill invalidated.
                    continue;
                }
                g.accumulate(t, window);
                g.iterating = false;
                // Advance every busy slot by one iteration (swap-remove on
                // completion: the slab stays dense, order is immaterial).
                let mut s = 0;
                while s < g.active.len() {
                    let a = &mut g.active[s];
                    a.iters_left -= 1;
                    if a.prefill_left > 0 {
                        a.prefill_left -= 1;
                    } else if !a.first_token_done {
                        // This iteration produced the first token.
                        a.first_token_done = true;
                        if a.req >= warm {
                            ttft.push(t - requests[a.req].arrival_s);
                        }
                    }
                    if a.iters_left == 0 {
                        if !a.first_token_done && a.req >= warm {
                            // Degenerate L_out: first token == last.
                            ttft.push(t - requests[a.req].arrival_s);
                        }
                        let done = g.active.swap_remove(s);
                        g.kv_reserved -= done.kv_tokens as u64;
                        completed += 1;
                    } else {
                        s += 1;
                    }
                }
                admit(g, queue, t, &mut wait, requests, warm, chunk, &mut kv);
                if g.n_busy() > 0 {
                    let dt = if cfg.lockstep_full {
                        t_iter_full
                    } else {
                        cfg.gpu.t_iter_s(g.n_busy())
                    };
                    g.iterating = true;
                    events.schedule(t + dt, Ev::Iteration(gi, g.gen));
                } else {
                    idle.insert(gi);
                }
            }
            Ev::Crash(gi, gen) => {
                let g = &mut gpus[gi];
                if g.down || g.gen != gen {
                    // An earlier kill or an outage beat this draw here.
                    continue;
                }
                if g.fail_preempt {
                    preemptions += 1;
                } else {
                    crashes += 1;
                }
                let mttr = g.fail_mttr;
                killed_in_flight += take_down(
                    g,
                    queue,
                    idle,
                    gi,
                    t,
                    window,
                    cfg.max_retries,
                    retries,
                    &mut dropped_retries,
                );
                let restore_gen = g.gen;
                if outage_depth == 0 {
                    // During an outage the pool-wide OutageEnd revives.
                    events.schedule(t + mttr, Ev::Restore(gi, restore_gen));
                }
                // The kill may have stranded requeued work while other
                // GPUs sit idle (idle GPUs are only woken by arrivals):
                // wake them now.
                while !queue.is_empty() {
                    let Some(wi) = idle.max() else { break };
                    let g = &mut gpus[wi];
                    debug_assert!(!g.iterating && g.active.is_empty() && !g.down);
                    g.accumulate(t, window);
                    admit(g, queue, t, &mut wait, requests, warm, chunk, &mut kv);
                    if g.n_busy() == 0 {
                        break;
                    }
                    let dt = if cfg.lockstep_full {
                        t_iter_full
                    } else {
                        cfg.gpu.t_iter_s(g.n_busy())
                    };
                    g.iterating = true;
                    idle.remove(wi);
                    events.schedule(t + dt, Ev::Iteration(wi, g.gen));
                }
            }
            Ev::Restore(gi, gen) => {
                let g = &mut gpus[gi];
                if !g.down || g.gen != gen {
                    continue;
                }
                if outage_depth > 0 {
                    // Personal restore inside an outage window defers to
                    // OutageEnd's mass revive.
                    continue;
                }
                g.accumulate(t, window);
                g.down = false;
                if let Some(fp) = &cfg.faults {
                    arm_fault(g, events, t, gi, fp);
                }
                admit(g, queue, t, &mut wait, requests, warm, chunk, &mut kv);
                if g.n_busy() > 0 {
                    let dt = if cfg.lockstep_full {
                        t_iter_full
                    } else {
                        cfg.gpu.t_iter_s(g.n_busy())
                    };
                    g.iterating = true;
                    events.schedule(t + dt, Ev::Iteration(gi, g.gen));
                } else {
                    idle.insert(gi);
                }
            }
            Ev::OutageStart => {
                outage_depth += 1;
                if outage_depth == 1 {
                    for gi in 0..n_gpus {
                        let g = &mut gpus[gi];
                        if g.down {
                            continue;
                        }
                        killed_in_flight += take_down(
                            g,
                            queue,
                            idle,
                            gi,
                            t,
                            window,
                            cfg.max_retries,
                            retries,
                            &mut dropped_retries,
                        );
                    }
                }
            }
            Ev::OutageEnd => {
                if outage_depth > 0 {
                    outage_depth -= 1;
                }
                if outage_depth == 0 {
                    for gi in 0..n_gpus {
                        let g = &mut gpus[gi];
                        if !g.down {
                            continue;
                        }
                        g.accumulate(t, window);
                        g.down = false;
                        if let Some(fp) = &cfg.faults {
                            arm_fault(g, events, t, gi, fp);
                        }
                        admit(g, queue, t, &mut wait, requests, warm, chunk, &mut kv);
                        if g.n_busy() > 0 {
                            let dt = if cfg.lockstep_full {
                                t_iter_full
                            } else {
                                cfg.gpu.t_iter_s(g.n_busy())
                            };
                            g.iterating = true;
                            events.schedule(t + dt, Ev::Iteration(gi, g.gen));
                        } else {
                            idle.insert(gi);
                        }
                    }
                }
            }
        }
    }

    let slot_time: f64 =
        cfg.n_gpus as f64 * cfg.n_slots as f64 * (window.1 - window.0).max(1e-12);
    let busy: f64 = gpus.iter().map(|g| g.busy_integral).sum();
    let kv_util = match cfg.kv_cap_tokens {
        Some(cap) if cap > 0 => {
            let kv_token_time: f64 =
                cfg.n_gpus as f64 * cap as f64 * (window.1 - window.0).max(1e-12);
            gpus.iter().map(|g| g.kv_integral).sum::<f64>() / kv_token_time
        }
        _ => 0.0,
    };
    SimResult {
        utilization: busy / slot_time,
        ttft,
        wait,
        completed,
        censored: n_req as u64 - completed - dropped_retries,
        window,
        events: n_events,
        crashes,
        preemptions,
        killed_in_flight,
        dropped_retries,
        kv_util,
        kv_blocked: kv.blocked,
        kv_violations: kv.violations,
    }
}

/// Run independent replications of one pool configuration in parallel
/// (§Perf): traces fan out over the shared [`crate::util::par`] substrate
/// (one capped worker per trace). Results are returned in input order and
/// each is bit-identical to a sequential `simulate_pool` call — the
/// simulator is deterministic and shares no mutable state across
/// replications.
pub fn simulate_pool_replications(
    cfg: &SimConfig,
    traces: &[Vec<SimRequest>],
) -> Vec<SimResult> {
    if traces.len() <= 1 {
        let mut scratch = SimScratch::new();
        return traces
            .iter()
            .map(|t| simulate_pool_with(cfg, t, &mut scratch))
            .collect();
    }
    crate::util::par::par_map_each(traces, |t| simulate_pool(cfg, t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gpu() -> GpuProfile {
        GpuProfile::a100_llama70b()
    }

    fn poisson_requests(
        lambda: f64,
        n: usize,
        l_in: u32,
        l_out: u32,
        seed: u64,
    ) -> Vec<SimRequest> {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += rng.exp(lambda);
                SimRequest {
                    arrival_s: t,
                    l_in,
                    l_out,
                }
            })
            .collect()
    }

    #[test]
    fn all_requests_complete() {
        let cfg = SimConfig::new(gpu(), 2, 16);
        let reqs = poisson_requests(5.0, 500, 1000, 50, 1);
        let res = simulate_pool(&cfg, &reqs);
        assert_eq!(res.completed, 500);
        assert_eq!(res.censored, 0);
        assert!(res.events >= 500, "every arrival is an event");
    }

    #[test]
    fn horizon_censors_in_flight_requests() {
        // Regression for the epoch-accounting edge: a truncated run must
        // count still-pending requests as censored, not drop them from
        // the percentile population.
        let mut cfg = SimConfig::new(gpu(), 1, 16);
        let reqs = poisson_requests(5.0, 400, 2048, 100, 9);
        let full = simulate_pool(&cfg, &reqs);
        assert_eq!(full.censored, 0);
        assert_eq!(full.completed, 400);
        // Cut mid-stream: arrivals past the horizon plus in-flight work
        // are censored, and the books still balance.
        cfg.horizon_s = Some(reqs[200].arrival_s);
        let cut = simulate_pool(&cfg, &reqs);
        assert!(cut.censored > 0, "expected censored requests");
        assert!(cut.completed < 400);
        assert_eq!(cut.completed + cut.censored, 400);
        // Completed-only samples: no more recorded TTFTs than completions.
        assert!(cut.ttft.len() as u64 <= cut.completed);
    }

    #[test]
    fn deterministic() {
        let cfg = SimConfig::new(gpu(), 3, 16);
        let reqs = poisson_requests(10.0, 1000, 800, 40, 2);
        let a = simulate_pool(&cfg, &reqs);
        let b = simulate_pool(&cfg, &reqs);
        assert_eq!(a.utilization, b.utilization);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn heap_oracle_is_bit_identical() {
        // The calendar queue vs the BinaryHeap oracle, end to end.
        let mut cfg = SimConfig::new(gpu(), 3, 16);
        let reqs = poisson_requests(12.0, 2_000, 1200, 60, 21);
        let cal = simulate_pool(&cfg, &reqs);
        cfg.queue_impl = QueueImpl::BinaryHeap;
        let heap = simulate_pool(&cfg, &reqs);
        assert_eq!(cal.utilization.to_bits(), heap.utilization.to_bits());
        assert_eq!(cal.completed, heap.completed);
        assert_eq!(cal.events, heap.events);
        let (mut a, mut b) = (cal.ttft, heap.ttft);
        assert_eq!(a.p99().to_bits(), b.p99().to_bits());
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let mut scratch = SimScratch::new();
        let cfg_a = SimConfig::new(gpu(), 2, 16);
        let cfg_b = SimConfig::new(gpu(), 5, 32);
        let ra = poisson_requests(8.0, 900, 700, 50, 5);
        let rb = poisson_requests(20.0, 1_200, 1500, 80, 6);
        // Interleave shapes so the scratch is re-shaped between runs.
        let a1 = simulate_pool_with(&cfg_a, &ra, &mut scratch);
        let b1 = simulate_pool_with(&cfg_b, &rb, &mut scratch);
        let a2 = simulate_pool_with(&cfg_a, &ra, &mut scratch);
        let fresh = simulate_pool(&cfg_b, &rb);
        assert_eq!(a1.utilization.to_bits(), a2.utilization.to_bits());
        assert_eq!(a1.completed, a2.completed);
        assert_eq!(b1.utilization.to_bits(), fresh.utilization.to_bits());
        assert_eq!(b1.completed, fresh.completed);
    }

    #[test]
    fn utilization_matches_littles_law() {
        // Deterministic service: E[S] = iters * t_iter; rho = lambda E[S] / c.
        let cfg = SimConfig::new(gpu(), 4, 16);
        let l_in = 1024u32; // 2 chunks
        let l_out = 98u32; // total 100 iters
        let t_iter = cfg.gpu.t_iter_s(16);
        let e_s = 100.0 * t_iter; // 1.84 s
        let lambda = 20.0;
        let rho_expect = lambda * e_s / (4.0 * 16.0);
        assert!(rho_expect < 0.85);
        let reqs = poisson_requests(lambda, 20_000, l_in, l_out, 3);
        let res = simulate_pool(&cfg, &reqs);
        assert!(
            (res.utilization - rho_expect).abs() / rho_expect < 0.03,
            "sim {} vs analytical {rho_expect}",
            res.utilization
        );
    }

    #[test]
    fn ttft_lower_bound_is_prefill_plus_decode() {
        // An unloaded pool: TTFT = (prefill chunks + 1) * t_iter exactly.
        let cfg = SimConfig::new(gpu(), 1, 16);
        let reqs = vec![SimRequest {
            arrival_s: 0.0,
            l_in: 1024,
            l_out: 10,
        }];
        let mut res = simulate_pool(&cfg, &reqs);
        // warmup_frac 0.1 of 1 request = 0 warm-up; sample recorded.
        let t_iter = cfg.gpu.t_iter_s(16);
        assert_eq!(res.ttft.len(), 1);
        assert!((res.ttft.p50() - 3.0 * t_iter).abs() < 1e-9);
    }

    #[test]
    fn queueing_appears_under_overload() {
        // One GPU, offered load > 1: waits must grow.
        let cfg = SimConfig::new(gpu(), 1, 16);
        let reqs = poisson_requests(50.0, 2_000, 2048, 100, 4);
        let mut res = simulate_pool(&cfg, &reqs);
        assert!(res.wait.p99() > 1.0, "p99 wait {}", res.wait.p99());
        assert!(res.utilization > 0.95);
    }

    #[test]
    fn occupancy_mode_faster_when_underloaded() {
        // With few busy slots, occupancy-dependent t_iter beats lockstep.
        let mut cfg = SimConfig::new(gpu(), 1, 128);
        let reqs = vec![SimRequest {
            arrival_s: 0.0,
            l_in: 512,
            l_out: 50,
        }];
        let full = simulate_pool(&cfg, &reqs);
        cfg.lockstep_full = false;
        let occ = simulate_pool(&cfg, &reqs);
        let mut f = full.ttft;
        let mut o = occ.ttft;
        assert!(o.p50() < f.p50());
    }

    #[test]
    fn parallel_replications_match_sequential() {
        let cfg = SimConfig::new(gpu(), 2, 16);
        let traces: Vec<Vec<SimRequest>> = (0..4)
            .map(|k| poisson_requests(8.0, 800, 900, 40, 100 + k))
            .collect();
        let par = simulate_pool_replications(&cfg, &traces);
        assert_eq!(par.len(), 4);
        for (p, t) in par.iter().zip(&traces) {
            let seq = simulate_pool(&cfg, t);
            assert_eq!(p.utilization, seq.utilization);
            assert_eq!(p.completed, seq.completed);
        }
    }

    #[test]
    fn unbinding_kv_cap_is_bit_identical_to_off() {
        // A cap no request population can reach changes no admission
        // decision: every observable except the KV diagnostics matches
        // the tracking-off engine bit-for-bit.
        let reqs = poisson_requests(10.0, 1_500, 1200, 60, 31);
        let off = simulate_pool(&SimConfig::new(gpu(), 3, 16), &reqs);
        let mut cfg = SimConfig::new(gpu(), 3, 16);
        cfg.kv_cap_tokens = Some(u64::MAX / 2);
        let on = simulate_pool(&cfg, &reqs);
        assert_eq!(on.utilization.to_bits(), off.utilization.to_bits());
        assert_eq!(on.completed, off.completed);
        assert_eq!(on.events, off.events);
        let (mut a, mut b) = (on.ttft, off.ttft);
        assert_eq!(a.p99().to_bits(), b.p99().to_bits());
        assert_eq!(on.kv_blocked, 0);
        assert_eq!(on.kv_violations, 0);
        assert!(on.kv_util > 0.0, "ledger measured under Some cap");
        assert_eq!(off.kv_util, 0.0, "no ledger without a cap");
    }

    #[test]
    fn kv_cap_blocks_head_of_line_without_violations() {
        // Cap fits ~4 of the 16-slot GPU's requests: KV (not slots) is
        // the binding resource. The run still drains — requests queue
        // rather than oversubscribe — and the ledger never exceeds cap.
        let mut cfg = SimConfig::new(gpu(), 1, 16);
        let reqs = poisson_requests(3.0, 600, 2048, 100, 32);
        cfg.kv_cap_tokens = Some(4 * 2148 + 100);
        let res = simulate_pool(&cfg, &reqs);
        assert_eq!(res.completed, 600);
        assert_eq!(res.censored, 0);
        assert!(res.kv_blocked > 0, "cap must have bound");
        assert_eq!(res.kv_violations, 0);
        assert!(res.kv_util <= 1.0 + 1e-9, "kv_util {}", res.kv_util);
        // Tighter decode memory means strictly more queueing than slots
        // alone would produce.
        let open = simulate_pool(&SimConfig::new(gpu(), 1, 16), &reqs);
        let (mut capped, mut free) = (res.wait, open.wait);
        assert!(capped.p99() >= free.p99());
    }

    #[test]
    fn kv_utilization_matches_littles_law() {
        // Deterministic sizes: E[(l_in+l_out) * T] * t_iter is exact, so
        // the measured mean reserved tokens must match lambda * e_kv_s.
        let mut cfg = SimConfig::new(gpu(), 4, 16);
        let cap = 50_000u64;
        cfg.kv_cap_tokens = Some(cap);
        let l_in = 1024u32; // 2 chunks
        let l_out = 98u32; // T = 100 iterations
        let t_iter = cfg.gpu.t_iter_s(16);
        let lambda = 20.0;
        let e_kv_s = (l_in + l_out) as f64 * 100.0 * t_iter;
        let rho_kv_expect = lambda * e_kv_s / (4.0 * cap as f64);
        let reqs = poisson_requests(lambda, 20_000, l_in, l_out, 33);
        let res = simulate_pool(&cfg, &reqs);
        assert!(
            (res.kv_util - rho_kv_expect).abs() / rho_kv_expect < 0.03,
            "kv_util {} vs analytical {rho_kv_expect}",
            res.kv_util
        );
    }

    #[test]
    fn retry_budget_without_faults_is_bit_identical() {
        let reqs = poisson_requests(9.0, 1_000, 900, 50, 34);
        let off = simulate_pool(&SimConfig::new(gpu(), 2, 16), &reqs);
        let mut cfg = SimConfig::new(gpu(), 2, 16);
        cfg.max_retries = Some(0);
        let on = simulate_pool(&cfg, &reqs);
        assert_eq!(on.utilization.to_bits(), off.utilization.to_bits());
        assert_eq!(on.completed, off.completed);
        assert_eq!(on.events, off.events);
        assert_eq!(on.dropped_retries, 0, "no faults, nothing to drop");
    }

    #[test]
    fn more_gpus_reduce_waits() {
        let reqs = poisson_requests(30.0, 3_000, 2048, 80, 5);
        let small = simulate_pool(&SimConfig::new(gpu(), 2, 16), &reqs);
        let big = simulate_pool(&SimConfig::new(gpu(), 8, 16), &reqs);
        let (mut s, mut b) = (small.wait, big.wait);
        assert!(b.p99() <= s.p99());
    }
}
