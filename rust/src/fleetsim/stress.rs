//! The million-scale DES stress archetype (ROADMAP "DES performance"):
//! a synthetic multi-million-request, multi-hundred-GPU, K-tier diurnal
//! scenario that must complete in **seconds** — the scale the calendar
//! queue, dense slot slabs, and idle bitsets were built for. The default
//! shape is 5M requests through a 512-GPU K = 4 fleet under a diurnal
//! wave; CI runs it in release through `cargo bench --bench
//! des_throughput` and gates the wall clock (< 30 s), and `fleetopt
//! simulate --stress` runs it from the CLI.
//!
//! Sizing is self-calibrating and deterministic: a small constant-rate
//! pilot trace measures each tier's traffic share and mean slot
//! occupancy, GPUs are split so tiers load evenly, and the base rate is
//! chosen so the diurnal *peak* keeps every tier at `target_rho` — the
//! run saturates the event loop, not the queues (an overloaded tier
//! would measure queue growth, not engine throughput).

use std::time::Instant;

use crate::config::GpuProfile;
use crate::fleetsim::events::QueueImpl;
use crate::fleetsim::fleet::{route_trace_tiered, route_trace_tiered_model};
use crate::fleetsim::sim::{simulate_pool, SimConfig, SimRequest, SimResult};
use crate::workload::arrivals::RateModel;
use crate::workload::traces::{self, Workload};

/// Stress-scenario shape. [`Default`] is the CI-gated 5M / 512-GPU / K=4
/// configuration.
#[derive(Clone, Debug)]
pub struct StressConfig {
    pub n_requests: usize,
    /// Total GPUs, split across tiers proportionally to offered load.
    pub n_gpus_total: u64,
    /// K ascending context windows (K-1 boundaries + the long window).
    pub windows: Vec<u32>,
    /// Shared per-boundary compression bandwidth.
    pub gamma: f64,
    /// Diurnal relative amplitude in [0, 1).
    pub diurnal_amp: f64,
    /// Full diurnal cycles over the run horizon.
    pub periods: f64,
    /// Per-tier utilization target at the diurnal peak.
    pub target_rho: f64,
    pub seed: u64,
    /// Scheduler backend (the heap oracle makes a before/after bench).
    pub queue_impl: QueueImpl,
}

impl Default for StressConfig {
    fn default() -> Self {
        StressConfig {
            n_requests: 5_000_000,
            n_gpus_total: 512,
            windows: vec![2048, 8192, 32_768, 65_536],
            gamma: 1.5,
            diurnal_amp: 0.4,
            periods: 2.0,
            target_rho: 0.7,
            seed: 0x57E55,
            queue_impl: QueueImpl::Calendar,
        }
    }
}

/// What the stress run measured.
#[derive(Debug)]
pub struct StressReport {
    pub n_requests: u64,
    pub completed: u64,
    pub censored: u64,
    /// Total discrete events processed across all tier simulations.
    pub events: u64,
    /// End-to-end wall time (pilot + trace generation + DES), seconds.
    pub wall_s: f64,
    /// Trace-generation and DES sub-timings, seconds.
    pub gen_s: f64,
    pub sim_s: f64,
    pub lambda_base: f64,
    pub horizon_s: f64,
    /// GPUs per tier (sums to the configured total).
    pub gpus: Vec<u64>,
    pub utilization: Vec<f64>,
    pub ttft_p99_s: Vec<f64>,
    pub wait_p99_s: Vec<f64>,
    pub n_compressed: u64,
}

impl StressReport {
    /// Events per wall-second through the DES phase.
    pub fn events_per_s(&self) -> f64 {
        self.events as f64 / self.sim_s.max(1e-9)
    }
}

/// Mean slot-seconds one request of `trace` occupies at `n_slots` (Eq. 4
/// iterations x the Eq. 3 lockstep latency) — the sizing primitive shared
/// with the `des_throughput` bench and the DES engine tests.
pub fn mean_occupancy_s(trace: &[SimRequest], g: &GpuProfile, n_slots: u32) -> f64 {
    if trace.is_empty() {
        return 0.0;
    }
    let t_iter = g.t_iter_s(n_slots);
    let total: f64 = trace
        .iter()
        .map(|r| ((r.l_in as u64).div_ceil(g.chunk as u64) + r.l_out as u64) as f64 * t_iter)
        .sum();
    total / trace.len() as f64
}

/// Run the stress scenario on the azure workload (the fat-tailed trace
/// with full compressibility — every boundary band sees C&R traffic).
pub fn run_stress(cfg: &StressConfig) -> StressReport {
    assert!(cfg.windows.len() >= 2, "need K >= 2 windows");
    assert!(
        cfg.windows.windows(2).all(|w| w[1] > w[0]),
        "windows must ascend"
    );
    assert!(cfg.n_requests > 0 && cfg.n_gpus_total as usize >= cfg.windows.len());
    assert!((0.0..1.0).contains(&cfg.diurnal_amp));
    assert!(cfg.target_rho > 0.0 && cfg.target_rho < 1.0);
    let t_start = Instant::now();

    let w: Workload = traces::azure();
    let mut g = GpuProfile::a100_llama70b();
    let k = cfg.windows.len();
    g.c_max_long = cfg.windows[k - 1];
    let boundaries: Vec<u32> = cfg.windows[..k - 1].to_vec();
    let gammas = vec![cfg.gamma; k - 1];
    let n_slots: Vec<u32> = cfg.windows.iter().map(|&win| g.n_max(win)).collect();

    // Pilot: constant-rate sample to estimate per-tier share and mean
    // occupancy (arrival times are irrelevant to both).
    let n_pilot = 20_000.min(cfg.n_requests);
    let pilot = route_trace_tiered(&w, 1000.0, n_pilot, &boundaries, &gammas, cfg.seed ^ 0x91);
    let share: Vec<f64> = pilot
        .tiers
        .iter()
        .map(|t| t.len() as f64 / n_pilot as f64)
        .collect();
    let occ: Vec<f64> = pilot
        .tiers
        .iter()
        .zip(&n_slots)
        .map(|(t, &s)| mean_occupancy_s(t, &g, s))
        .collect();

    // GPU split proportional to offered GPU-load (equalizes tier rho),
    // largest-remainder rounding, one-GPU floor per tier.
    let mut weights = vec![0.0f64; k];
    for i in 0..k {
        weights[i] = share[i] * occ[i] / n_slots[i] as f64;
    }
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0, "pilot produced no load");
    let mut gpus: Vec<u64> = weights
        .iter()
        .map(|&wt| ((cfg.n_gpus_total as f64 * wt / wsum).floor() as u64).max(1))
        .collect();
    let mut assigned: u64 = gpus.iter().sum();
    // Hand remaining GPUs to tiers by descending fractional remainder
    // (deterministic: stable sort, index tiebreak).
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| {
        let fa = cfg.n_gpus_total as f64 * weights[a] / wsum;
        let fb = cfg.n_gpus_total as f64 * weights[b] / wsum;
        (fb - fb.floor()).total_cmp(&(fa - fa.floor())).then(a.cmp(&b))
    });
    let mut oi = 0;
    while assigned < cfg.n_gpus_total {
        gpus[order[oi % k]] += 1;
        assigned += 1;
        oi += 1;
    }
    while assigned > cfg.n_gpus_total {
        // Floors pushed us over: shave the largest tier.
        let imax = (0..k).max_by_key(|&i| gpus[i]).expect("k >= 2");
        assert!(gpus[imax] > 1, "cannot satisfy per-tier GPU floors");
        gpus[imax] -= 1;
        assigned -= 1;
    }

    // Base rate: the diurnal peak holds every tier at target_rho.
    let mut lambda_peak = f64::INFINITY;
    for i in 0..k {
        if share[i] > 0.0 && occ[i] > 0.0 {
            let cap = gpus[i] as f64 * n_slots[i] as f64 * cfg.target_rho / (share[i] * occ[i]);
            lambda_peak = lambda_peak.min(cap);
        }
    }
    assert!(lambda_peak.is_finite() && lambda_peak > 0.0);
    let lambda_base = lambda_peak / (1.0 + cfg.diurnal_amp);
    let horizon_s = cfg.n_requests as f64 / lambda_base;
    let model = RateModel::Diurnal {
        base: lambda_base,
        amp: cfg.diurnal_amp,
        period_s: horizon_s / cfg.periods,
        phase: 0.0,
    };

    // Full trace + one capped DES worker per tier (util::par substrate).
    let t_gen = Instant::now();
    let routed =
        route_trace_tiered_model(&w, &model, cfg.n_requests, &boundaries, &gammas, cfg.seed);
    let gen_s = t_gen.elapsed().as_secs_f64();
    let t_sim = Instant::now();
    let items: Vec<(usize, &Vec<SimRequest>)> = routed.tiers.iter().enumerate().collect();
    let results: Vec<Option<SimResult>> =
        crate::util::par::par_map_each(&items, |&(ti, trace)| {
            (!trace.is_empty()).then(|| {
                let mut sc = SimConfig::new(g.clone(), gpus[ti], n_slots[ti]);
                sc.queue_impl = cfg.queue_impl;
                simulate_pool(&sc, trace)
            })
        });
    let sim_s = t_sim.elapsed().as_secs_f64();

    let mut completed = 0u64;
    let mut censored = 0u64;
    let mut events = 0u64;
    let mut utilization = Vec::with_capacity(k);
    let mut ttft_p99_s = Vec::with_capacity(k);
    let mut wait_p99_s = Vec::with_capacity(k);
    for res in results {
        match res {
            Some(mut r) => {
                completed += r.completed;
                censored += r.censored;
                events += r.events;
                utilization.push(r.utilization);
                ttft_p99_s.push(if r.ttft.is_empty() { 0.0 } else { r.ttft.p99() });
                wait_p99_s.push(if r.wait.is_empty() { 0.0 } else { r.wait.p99() });
            }
            None => {
                utilization.push(0.0);
                ttft_p99_s.push(0.0);
                wait_p99_s.push(0.0);
            }
        }
    }
    StressReport {
        n_requests: cfg.n_requests as u64,
        completed,
        censored,
        events,
        wall_s: t_start.elapsed().as_secs_f64(),
        gen_s,
        sim_s,
        lambda_base,
        horizon_s,
        gpus,
        utilization,
        ttft_p99_s,
        wait_p99_s,
        n_compressed: routed.n_compressed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> StressConfig {
        StressConfig {
            n_requests: 15_000,
            n_gpus_total: 24,
            windows: vec![2048, 8192, 65_536],
            periods: 1.0,
            ..StressConfig::default()
        }
    }

    #[test]
    fn stress_completes_every_request() {
        let rep = run_stress(&tiny());
        assert_eq!(rep.completed, 15_000);
        assert_eq!(rep.censored, 0);
        assert_eq!(rep.gpus.iter().sum::<u64>(), 24);
        assert!(rep.events > 15_000, "iterations must add events");
        assert!(rep.lambda_base > 0.0 && rep.horizon_s > 0.0);
        // Sized for target_rho at peak: no tier should run saturated.
        for (ti, &u) in rep.utilization.iter().enumerate() {
            assert!(u < 0.95, "tier {ti} saturated: rho {u}");
        }
    }

    #[test]
    fn stress_heap_oracle_matches_calendar() {
        let cal = run_stress(&tiny());
        let mut hcfg = tiny();
        hcfg.queue_impl = QueueImpl::BinaryHeap;
        let heap = run_stress(&hcfg);
        assert_eq!(cal.completed, heap.completed);
        assert_eq!(cal.events, heap.events);
        for (a, b) in cal.utilization.iter().zip(&heap.utilization) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in cal.ttft_p99_s.iter().zip(&heap.ttft_p99_s) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
