//! Deterministic discrete-event queues for the fleet simulator.
//!
//! Two interchangeable schedulers behind one [`EventQueue`] API, both
//! popping in exact `(time, seq)` order — the sequence number breaks time
//! ties in insertion order, so runs are bit-reproducible under a fixed
//! seed (the property the Table-5 validation leans on):
//!
//! * [`QueueImpl::Calendar`] (default) — a Brown-style calendar queue:
//!   events hash into time-width buckets on a ring, pops scan only the
//!   current bucket's due events. Push and pop are O(1) amortized and
//!   touch one or two cache lines, where a binary heap over millions of
//!   pre-scheduled arrivals pays O(log n) sift steps of random access per
//!   operation. Bucket storage is recycled — the queue allocates nothing
//!   in steady state.
//! * [`QueueImpl::BinaryHeap`] — the original heap, kept verbatim as the
//!   **equivalence oracle**: `tests/des_engine.rs` property-tests that the
//!   two backends produce byte-identical pop sequences under random
//!   schedules (including exact time ties), the same way
//!   `SimilarityMode::AllPairs` anchors the compressor's inverted index.
//!
//! Scheduling into the past is a real error path, not a debug-only
//! assert: [`EventQueue::schedule`] clamps the event to `now` and counts
//! it (see [`EventQueue::clamped`]), and [`EventQueue::schedule_checked`]
//! refuses outright — a past event would silently rewind simulation time
//! on the heap and could be mis-filed by the calendar's year windows.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event at `time` carrying a payload `E`.
#[derive(Clone, Debug)]
struct Scheduled<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; total_cmp gives a total order on f64.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Which scheduler backs an [`EventQueue`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueImpl {
    /// Calendar queue: O(1) amortized push/pop (the default).
    #[default]
    Calendar,
    /// The original binary heap — the equivalence oracle.
    BinaryHeap,
}

/// Attempted to schedule an event before the current simulation time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PastScheduleError {
    /// The rejected timestamp.
    pub time: f64,
    /// Simulation time when the schedule was attempted.
    pub now: f64,
}

impl std::fmt::Display for PastScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "scheduling into the past: {} < {}", self.time, self.now)
    }
}

impl std::error::Error for PastScheduleError {}

/// Calendar-queue bucket count bounds. The bucket ring doubles while the
/// queue grows past two events per bucket and halves as it drains, so pop
/// scans stay O(events / buckets) = O(1) amortized; the cap bounds the
/// ring's memory at ~2 MB of `Vec` headers even for multi-million-event
/// preloads (a few events per bucket is still a one-cache-line scan).
const MIN_BUCKETS: usize = 1 << 6;
const MAX_BUCKETS: usize = 1 << 17;

/// Brown's calendar queue specialized to the DES: unsorted buckets over a
/// ring of fixed-width windows, min-scanned per pop.
///
/// **Exactness does not depend on the width tuning — only speed does.**
/// One quotient function `q(t) = (t * inv_width) as u64` drives bucket
/// assignment, the cursor, and the due filter; it is monotone in `t`
/// (multiplication by a positive constant, then a floor), so events in a
/// later window are never earlier in time, equal times always share a
/// window (hence a bucket — tie order reduces to the in-bucket seq scan),
/// and the scan's first hit is the global minimum. Two invariants keep
/// that sound: events are never admitted before `now` (the `EventQueue`
/// clamps), and the cursor never anchors past `now`'s window (resizes
/// anchor at `now` itself) — so no event can hide in a window behind the
/// cursor. A full fruitless ring round (sparse queue) falls back to a
/// direct global-minimum search and re-anchors.
#[derive(Debug)]
struct Calendar<E> {
    buckets: Vec<Vec<Scheduled<E>>>,
    /// `buckets.len() - 1`; the length is a power of two.
    mask: usize,
    /// Bucket time width, seconds (and its reciprocal, the quotient
    /// multiplier — all window math goes through `inv_width`).
    width: f64,
    inv_width: f64,
    /// Absolute window index the next pop scan starts at; the ring bucket
    /// is `abs_win & mask`.
    abs_win: u64,
    len: usize,
}

impl<E> Calendar<E> {
    fn new() -> Self {
        Calendar {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            mask: MIN_BUCKETS - 1,
            width: 1.0,
            inv_width: 1.0,
            abs_win: 0,
            len: 0,
        }
    }

    /// Absolute window index of `time` (saturating f64 cast; the width
    /// floor at resize keeps quotients far below u64::MAX).
    fn quotient(&self, time: f64) -> u64 {
        (time * self.inv_width) as u64
    }

    fn push(&mut self, time: f64, seq: u64, payload: E, now: f64) {
        if self.len > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            self.resize(self.buckets.len() * 2, now);
        }
        let b = (self.quotient(time) & self.mask as u64) as usize;
        self.buckets[b].push(Scheduled { time, seq, payload });
        self.len += 1;
    }

    /// Point the scan cursor at the window containing `time`.
    fn anchor(&mut self, time: f64) {
        self.abs_win = self.quotient(time);
    }

    /// Rebuild with `n_new` buckets and a freshly estimated width, then
    /// re-anchor at `now` — anchoring late could hide a subsequent insert
    /// (which is only bounded below by `now`) behind the cursor.
    fn resize(&mut self, n_new: usize, now: f64) {
        let mut all: Vec<Scheduled<E>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            all.append(b);
        }
        let mut t_min = f64::INFINITY;
        let mut t_max = f64::NEG_INFINITY;
        for s in &all {
            t_min = t_min.min(s.time);
            t_max = t_max.max(s.time);
        }
        let span = t_max - t_min;
        let mut width = if all.len() >= 2 && span > 0.0 && span.is_finite() {
            span / all.len() as f64
        } else {
            self.width
        };
        // Floor the width so quotients can never overflow u64 (and never
        // hit subnormals): at least time_scale * 1e-12.
        let floor = t_max.abs().max(now.abs()).max(1.0) * 1e-12;
        if !width.is_finite() || width < floor {
            width = floor;
        }
        self.buckets = (0..n_new).map(|_| Vec::new()).collect();
        self.mask = n_new - 1;
        self.width = width;
        self.inv_width = 1.0 / width;
        for s in all {
            let b = (self.quotient(s.time) & self.mask as u64) as usize;
            self.buckets[b].push(s);
        }
        self.anchor(now.max(0.0));
    }

    fn pop(&mut self, now: f64) -> Option<Scheduled<E>> {
        if self.len == 0 {
            return None;
        }
        if self.len * 4 < self.buckets.len() && self.buckets.len() > MIN_BUCKETS {
            // Shrink to the final size in one rebuild (a drained or
            // re-used queue would otherwise pay a halving chain of
            // full redistributions, one per pop).
            let mut target = self.buckets.len();
            while self.len * 4 < target && target > MIN_BUCKETS {
                target /= 2;
            }
            self.resize(target, now);
        }
        // Scan the ring one window at a time: the first bucket holding an
        // event of its current window holds the global minimum (windows
        // before the cursor are provably empty; quotients are monotone).
        let n = self.buckets.len();
        for _ in 0..n {
            let cur = (self.abs_win & self.mask as u64) as usize;
            let bucket = &mut self.buckets[cur];
            let mut best: Option<usize> = None;
            for (i, s) in bucket.iter().enumerate() {
                if (s.time * self.inv_width) as u64 == self.abs_win {
                    let better = match best {
                        None => true,
                        Some(j) => {
                            let b = &bucket[j];
                            s.time < b.time || (s.time == b.time && s.seq < b.seq)
                        }
                    };
                    if better {
                        best = Some(i);
                    }
                }
            }
            if let Some(i) = best {
                self.len -= 1;
                return Some(bucket.swap_remove(i));
            }
            self.abs_win += 1;
        }
        // A full round found nothing: the queue is sparse relative to its
        // span. Direct search for the global min, then re-anchor there.
        let mut at: Option<(usize, usize)> = None;
        let mut bt = f64::INFINITY;
        let mut bs = u64::MAX;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, s) in bucket.iter().enumerate() {
                if s.time < bt || (s.time == bt && s.seq < bs) {
                    bt = s.time;
                    bs = s.seq;
                    at = Some((b, i));
                }
            }
        }
        let (b, i) = at.expect("len > 0 but no event found");
        self.len -= 1;
        let s = self.buckets[b].swap_remove(i);
        self.anchor(s.time);
        Some(s)
    }

    /// Drop all events, keeping the ring and its bucket capacity.
    fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.len = 0;
        self.anchor(0.0);
    }
}

#[derive(Debug)]
enum Backend<E> {
    Calendar(Calendar<E>),
    Heap(BinaryHeap<Scheduled<E>>),
}

/// Min-time event queue (see module docs for the two backends).
#[derive(Debug)]
pub struct EventQueue<E> {
    backend: Backend<E>,
    seq: u64,
    now: f64,
    clamped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// A calendar-queue-backed event queue (the fast default).
    pub fn new() -> Self {
        Self::with_impl(QueueImpl::Calendar)
    }

    /// Choose the scheduler backend explicitly (the binary heap is the
    /// equivalence oracle for tests and benches).
    pub fn with_impl(which: QueueImpl) -> Self {
        let backend = match which {
            QueueImpl::Calendar => Backend::Calendar(Calendar::new()),
            QueueImpl::BinaryHeap => Backend::Heap(BinaryHeap::new()),
        };
        EventQueue {
            backend,
            seq: 0,
            now: 0.0,
            clamped: 0,
        }
    }

    /// Which backend this queue runs on.
    pub fn queue_impl(&self) -> QueueImpl {
        match self.backend {
            Backend::Calendar(_) => QueueImpl::Calendar,
            Backend::Heap(_) => QueueImpl::BinaryHeap,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Calendar(c) => c.len,
            Backend::Heap(h) => h.len(),
        }
    }

    /// Events that arrived with a timestamp in the past and were clamped
    /// to `now` by [`EventQueue::schedule`]. Always 0 in a healthy
    /// simulation; the autoscale DES surfaces this in its report.
    pub fn clamped(&self) -> u64 {
        self.clamped
    }

    fn push(&mut self, time: f64, payload: E) {
        let seq = self.seq;
        self.seq += 1;
        let now = self.now;
        match &mut self.backend {
            Backend::Calendar(c) => c.push(time, seq, payload, now),
            Backend::Heap(h) => h.push(Scheduled { time, seq, payload }),
        }
    }

    /// Schedule `payload` at absolute time `time`. A past (or NaN) time is
    /// clamped to `now` and counted in [`EventQueue::clamped`] — time
    /// travel would rewind the clock on the heap backend and corrupt the
    /// calendar's window accounting, so it is never admitted.
    pub fn schedule(&mut self, time: f64, payload: E) {
        debug_assert!(
            time >= self.now,
            "scheduling into the past: {time} < {}",
            self.now
        );
        let t = if time >= self.now {
            time
        } else {
            self.clamped += 1;
            self.now
        };
        self.push(t, payload);
    }

    /// Schedule `payload` at `time`, refusing (payload dropped, nothing
    /// enqueued) if `time` is in the past. Callers that must not lose the
    /// event handle the error explicitly — e.g. re-schedule at
    /// [`EventQueue::now`] and log.
    pub fn schedule_checked(&mut self, time: f64, payload: E) -> Result<(), PastScheduleError> {
        if time < self.now || time.is_nan() {
            return Err(PastScheduleError {
                time,
                now: self.now,
            });
        }
        self.push(time, payload);
        Ok(())
    }

    /// Pop the earliest event, advancing `now`.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let now = self.now;
        let s = match &mut self.backend {
            Backend::Calendar(c) => c.pop(now),
            Backend::Heap(h) => h.pop(),
        };
        s.map(|s| {
            self.now = s.time;
            (s.time, s.payload)
        })
    }

    /// Reset to the pristine state (now = 0, seq = 0, no events), keeping
    /// allocated bucket capacity — the scratch-reuse hook (§Perf).
    pub fn reset(&mut self) {
        match &mut self.backend {
            Backend::Calendar(c) => c.clear(),
            Backend::Heap(h) => h.clear(),
        }
        self.seq = 0;
        self.now = 0.0;
        self.clamped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both() -> [EventQueue<&'static str>; 2] {
        [
            EventQueue::with_impl(QueueImpl::Calendar),
            EventQueue::with_impl(QueueImpl::BinaryHeap),
        ]
    }

    #[test]
    fn pops_in_time_order() {
        for mut q in both() {
            q.schedule(3.0, "c");
            q.schedule(1.0, "a");
            q.schedule(2.0, "b");
            assert_eq!(q.pop().unwrap(), (1.0, "a"));
            assert_eq!(q.pop().unwrap(), (2.0, "b"));
            assert_eq!(q.pop().unwrap(), (3.0, "c"));
            assert!(q.pop().is_none());
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for which in [QueueImpl::Calendar, QueueImpl::BinaryHeap] {
            let mut q = EventQueue::with_impl(which);
            q.schedule(1.0, 1);
            q.schedule(1.0, 2);
            q.schedule(1.0, 3);
            assert_eq!(q.pop().unwrap().1, 1);
            assert_eq!(q.pop().unwrap().1, 2);
            assert_eq!(q.pop().unwrap().1, 3);
        }
    }

    #[test]
    fn now_tracks_popped_time() {
        for which in [QueueImpl::Calendar, QueueImpl::BinaryHeap] {
            let mut q = EventQueue::with_impl(which);
            assert_eq!(q.now(), 0.0);
            q.schedule(5.5, ());
            q.pop();
            assert_eq!(q.now(), 5.5);
        }
    }

    #[test]
    fn interleaved_schedule_pop() {
        for mut q in both() {
            q.schedule(1.0, "first");
            let (t, _) = q.pop().unwrap();
            q.schedule(t + 0.5, "second");
            q.schedule(t + 0.25, "before-second");
            assert_eq!(q.pop().unwrap().1, "before-second");
            assert_eq!(q.pop().unwrap().1, "second");
        }
    }

    #[test]
    fn len_and_empty() {
        for which in [QueueImpl::Calendar, QueueImpl::BinaryHeap] {
            let mut q = EventQueue::with_impl(which);
            assert!(q.is_empty());
            q.schedule(1.0, ());
            q.schedule(2.0, ());
            assert_eq!(q.len(), 2);
            q.pop();
            assert_eq!(q.len(), 1);
        }
    }

    #[test]
    fn grows_and_shrinks_through_bulk_load() {
        // Push far past the growth threshold, then drain fully: order must
        // hold through every resize.
        let mut q: EventQueue<usize> = EventQueue::new();
        let n = 10_000;
        for i in 0..n {
            // A deterministic scatter of times with many exact ties.
            let t = ((i * 7919) % 1000) as f64 * 0.125;
            q.schedule(t, i);
        }
        let mut last = (f64::NEG_INFINITY, 0usize);
        let mut popped = 0;
        while let Some((t, i)) = q.pop() {
            assert!(t >= last.0, "time went backwards: {t} after {}", last.0);
            popped += 1;
            last = (t, i);
        }
        assert_eq!(popped, n);
    }

    #[test]
    fn sparse_far_future_events_found_by_direct_search() {
        let mut q: EventQueue<u32> = EventQueue::new();
        // Dense cluster to set a narrow width...
        for i in 0..200 {
            q.schedule(i as f64 * 1e-3, i);
        }
        // ...then one event years past the ring's span.
        q.schedule(1.0e6, 999);
        for _ in 0..200 {
            assert_ne!(q.pop().unwrap().1, 999);
        }
        assert_eq!(q.pop().unwrap(), (1.0e6, 999));
        assert!(q.pop().is_none());
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "scheduling into the past"))]
    fn past_schedule_clamps_and_counts_in_release() {
        let mut q: EventQueue<&str> = EventQueue::new();
        q.schedule(10.0, "a");
        q.pop();
        // Debug builds keep the loud assert; release clamps and counts.
        q.schedule(3.0, "late");
        assert_eq!(q.clamped(), 1);
        let (t, p) = q.pop().unwrap();
        assert_eq!((t, p), (10.0, "late"));
        assert_eq!(q.now(), 10.0, "clock must never rewind");
    }

    #[test]
    fn schedule_checked_rejects_past_times() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.schedule(5.0, 1);
        q.pop();
        let err = q.schedule_checked(4.0, 2).unwrap_err();
        assert_eq!(err.time, 4.0);
        assert_eq!(err.now, 5.0);
        assert!(q.is_empty(), "rejected event must not be enqueued");
        assert!(q.schedule_checked(5.0, 3).is_ok());
        assert_eq!(q.pop().unwrap(), (5.0, 3));
        assert_eq!(q.clamped(), 0, "checked rejections are not clamps");
        let nan = q.schedule_checked(f64::NAN, 4);
        assert!(nan.is_err(), "NaN times are refused");
    }

    #[test]
    fn reset_reuses_capacity_and_restarts_seq() {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..500 {
            q.schedule(i as f64, i);
        }
        while q.pop().is_some() {}
        q.reset();
        assert_eq!(q.now(), 0.0);
        // Tie order restarts from seq 0 exactly like a fresh queue.
        q.schedule(1.0, 10);
        q.schedule(1.0, 20);
        assert_eq!(q.pop().unwrap().1, 10);
        assert_eq!(q.pop().unwrap().1, 20);
    }
}
