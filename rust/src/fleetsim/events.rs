//! Deterministic discrete-event queue for the fleet simulator.
//!
//! A binary heap keyed on (time, sequence): the sequence number breaks
//! time ties in insertion order, so runs are bit-reproducible under a
//! fixed seed — the property the Table-5 validation leans on.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event at `time` carrying a payload `E`.
#[derive(Clone, Debug)]
struct Scheduled<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; total_cmp gives a total order on f64.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-time event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `time` (must not be in the past).
    pub fn schedule(&mut self, time: f64, payload: E) {
        debug_assert!(
            time >= self.now,
            "scheduling into the past: {time} < {}",
            self.now
        );
        self.heap.push(Scheduled {
            time,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing `now`.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            self.now = s.time;
            (s.time, s.payload)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn now_tracks_popped_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.now(), 0.0);
        q.schedule(5.5, ());
        q.pop();
        assert_eq!(q.now(), 5.5);
    }

    #[test]
    fn interleaved_schedule_pop() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "first");
        let (t, _) = q.pop().unwrap();
        q.schedule(t + 0.5, "second");
        q.schedule(t + 0.25, "before-second");
        assert_eq!(q.pop().unwrap().1, "before-second");
        assert_eq!(q.pop().unwrap().1, "second");
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(1.0, ());
        q.schedule(2.0, ());
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }
}
