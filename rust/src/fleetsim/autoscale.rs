//! Autoscaling fleet DES: the discrete-event half of the online control
//! loop. Where [`crate::fleetsim::fleet::simulate_fleet_tiered`] replays a
//! *fixed* plan against a stationary trace, this simulator drives a K-tier
//! fleet through a **nonstationary** arrival process with a periodic
//! controller in the loop:
//!
//! * every `epoch_s` the controller reads the sliding-window estimator
//!   (rate + empirical CDF), calls the hysteretic
//!   [`Replanner`](crate::planner::replan::Replanner), and rescales;
//! * scale-**up** materializes after a provisioning (cold-start) delay;
//! * scale-**down** drains: a victim GPU stops admitting, finishes its
//!   in-flight requests, then leaves the fleet — no request is ever
//!   dropped or duplicated (property-tested in
//!   `tests/autoscale_control.rs`);
//! * per-epoch utilization / P99 TTFT / GPU-hour series come out as
//!   [`EpochMetrics`] — the evidence Table 9 and the CI smoke run consume.
//!
//! The per-GPU service model is exactly the lockstep-iteration model of
//! [`crate::fleetsim::sim`] (Eq. 3–4, chunked prefill, first token after
//! prefill + one decode step); routing across boundaries is decision-for-
//! decision the same as `route_trace_tiered`, re-evaluated per arrival so
//! a layout switch (a *software* re-tiering — the paper's central claim)
//! takes effect immediately while hardware changes wait out the
//! provisioning delay.
//!
//! §Perf (DES engine overhaul): busy slots live in dense per-GPU slabs,
//! arrivals wake GPUs through a per-tier [`IdleSet`] bitset instead of an
//! O(n_gpus) scan, per-epoch P99s stream through P² digests
//! ([`EpochDigest`] — exact up to a 2048-sample head, P² beyond; bounded
//! memory, reset without allocation; error bounds tested in
//! `tests/des_engine.rs`), and controller events scheduled into
//! the past are surfaced in [`AutoscaleReport::time_travel_events`]
//! instead of a release-stripped `debug_assert` silently rewinding time.

use std::collections::VecDeque;

use crate::fleetsim::events::EventQueue;
use crate::fleetsim::faults::FaultPlan;
use crate::fleetsim::idle::IdleSet;
use crate::metrics::{EpochDigest, EpochMetrics, EpochTierMetrics};
use crate::planner::replan::{ReplanConfig, Replanner};
use crate::planner::{PlanInput, TieredPlan};
use crate::queueing::kv::KvPlanPolicy;
use crate::router::admit::{
    decide, tightened_gammas, AdmitConfig, AdmitCounters, AdmitDecision, AdmitState,
};
use crate::router::failover::{effective_routes, FailoverConfig, FailoverState};
use crate::util::rng::Rng;
use crate::workload::arrivals::{ArrivalProcess, NonstationaryArrivals, RateModel};
use crate::workload::online::{OnlineEstimator, SeasonalEstimator};
use crate::workload::request::Request;
use crate::workload::traces::Workload;

/// Control-loop configuration for [`simulate_autoscale`].
#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    /// Controller period, seconds.
    pub epoch_s: f64,
    /// Sliding estimation window, seconds (typically 2x the epoch).
    pub window_s: f64,
    /// Cold-start delay before a scaled-up GPU serves traffic, seconds.
    pub provision_delay_s: f64,
    /// Floor per tier (>= 1: a tier must keep one GPU so queued traffic
    /// can always eventually drain).
    pub min_gpus_per_tier: u64,
    /// Hysteresis knobs for the incremental planner.
    pub replan: ReplanConfig,
    /// Multiplier on the estimated rate before planning (> 1 buys slack
    /// against estimator lag plus the provisioning delay on upswings —
    /// during the cold-start window demand keeps growing past whatever
    /// was just provisioned).
    pub target_headroom: f64,
    /// `false` freezes the initial plan (the static baselines of Table 9
    /// run through the identical simulator, controller off).
    pub replanning: bool,
    /// Anticipatory scaling (off by default): plan against
    /// `max(peak-window, one-epoch-ahead linear forecast)` instead of the
    /// peak alone ([`OnlineEstimator::forecast_rate`]) — cuts the
    /// remaining upswing lag the reactive peak estimate cannot see.
    /// Off, the controller is bit-identical to the reactive one
    /// (property-tested: the knob only ever *raises* the planning rate).
    pub forecast: bool,
    /// Crash-retry budget per request (chaos runs): a request killed more
    /// than this many times is dropped — accounted in
    /// [`AutoscaleReport::dropped_retries`], never requeued again.
    /// `None` (default) retries forever, bit-identical to the pre-budget
    /// engine (tested in `tests/chaos_conservation.rs`).
    pub max_retries: Option<u32>,
    /// Period of the seasonal (period-aware) forecaster, seconds
    /// (`None` = off, bit-identical). When set, each epoch's windowed
    /// rate is folded into a phase bin of the period
    /// ([`SeasonalEstimator`]) and planning uses the larger of the
    /// reactive estimate and the next epoch's same-phase seasonal mean —
    /// like `forecast`, the knob only ever *raises* the planning rate.
    pub seasonal_period_s: Option<f64>,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            epoch_s: 30.0,
            window_s: 60.0,
            provision_delay_s: 10.0,
            min_gpus_per_tier: 1,
            replan: ReplanConfig::default(),
            target_headroom: 1.10,
            replanning: true,
            forecast: false,
            max_retries: None,
            seasonal_period_s: None,
        }
    }
}

/// Chaos options for [`simulate_autoscale_chaos`]: failure injection and
/// the failure response. The default (no faults, no failover) keeps the
/// simulation bit-identical to [`simulate_autoscale`] — chaos is a pure
/// extension, never a behavior change (tested in
/// `tests/chaos_conservation.rs`).
#[derive(Clone, Debug, Default)]
pub struct ChaosOpts {
    /// Seeded failure processes to inject (None = immortal fleet).
    pub faults: Option<FaultPlan>,
    /// Degraded-capacity failover: spill borderline traffic across tier
    /// boundaries while a tier sits below its capacity watermark
    /// (None = route on the planned boundaries regardless of health).
    pub failover: Option<FailoverConfig>,
}

/// KV-cache options for [`simulate_autoscale_kv`]: the decode-phase
/// memory ledger and the admission controller in front of the ladder.
/// The default (no cap, no admission) keeps the simulation bit-identical
/// to [`simulate_autoscale_chaos`] — KV modeling is a pure extension,
/// never a behavior change (tested in `tests/kv_stability.rs` and
/// `tests/admission_control.rs`).
#[derive(Clone, Debug, Default)]
pub struct KvFleetOpts {
    /// Fraction of each tier's `n_max * c_max` token budget available to
    /// request KV ([`KvPlanPolicy`]); per-GPU caps are re-derived on
    /// every layout switch. `None` = no KV bookkeeping.
    pub cap_frac: Option<f64>,
    /// Watermark-hysteresis admission control (admit / compress-harder /
    /// defer / shed) driven by per-tier projected KV occupancy. `None` =
    /// every arrival admits exactly as before. Only meaningful together
    /// with `cap_frac` — without a cap every occupancy reads 0.0 and the
    /// controller never engages.
    pub admit: Option<AdmitConfig>,
}

/// Whole-run results of an autoscaled simulation.
#[derive(Debug)]
pub struct AutoscaleReport {
    pub epochs: Vec<EpochMetrics>,
    pub n_total: u64,
    pub completed: u64,
    /// Requests never completed, shed, or dropped (0 unless the run was
    /// cut short — the conservation property the drain logic is tested
    /// against: `completed + admit.shed + dropped_retries + censored ==
    /// n_total`).
    pub censored: u64,
    /// Requests compressed down across a boundary (C&R).
    pub n_compressed: u64,
    /// Provisioned GPU-time over the run, hours.
    pub gpu_hours: f64,
    /// GPU-time priced at the per-tier rates, dollars.
    pub cost: f64,
    /// Time of the last completion, seconds.
    pub horizon_s: f64,
    /// Fraction of epochs in which every tier met its queue-wait SLO
    /// budget (see [`crate::metrics::EpochTierMetrics::wait_p99_s`]).
    pub slo_ok_frac: f64,
    pub layout_switches: u64,
    /// GPUs alive per tier at the end of the run.
    pub final_gpus: Vec<u64>,
    /// Events that arrived at the scheduler with a timestamp in the past
    /// and were clamped to the current time (and logged) — 0 in a healthy
    /// run. Previously a `debug_assert` compiled out of release builds.
    pub time_travel_events: u64,
    /// Replica crash events that struck a serving GPU (chaos runs only).
    pub crashes: u64,
    /// Spot preemptions that struck a serving GPU (chaos runs only).
    pub preemptions: u64,
    /// In-flight requests killed by a crash/preemption/outage and requeued
    /// at the head of their tier's queue.
    pub killed_in_flight: u64,
    /// Total retry attempts across all requests; exactly equals
    /// `killed_in_flight` (every kill is one retry — the conservation
    /// identity `tests/chaos_conservation.rs` pins).
    pub retries_total: u64,
    /// Largest per-request retry count.
    pub max_retry: u32,
    /// Arrivals routed to a different tier than the healthy ladder would
    /// have chosen, because failover dropped or tightened a boundary.
    pub spilled: u64,
    /// Requests dropped after exhausting the crash-retry budget
    /// ([`AutoscaleConfig::max_retries`]; always 0 when unbounded).
    pub dropped_retries: u64,
    /// Admission-controller decision counters (all zero with admission
    /// off; `admitted + recompressed + admit.shed` tallies each offered
    /// request once by its terminal decision, `deferred` counts retry
    /// deadlines granted along the way).
    pub admit: AdmitCounters,
    /// Head-of-line admissions blocked on the KV gate (KV runs only).
    pub kv_blocked: u64,
    /// Reservations that exceeded a GPU's KV capacity — impossible by
    /// construction except for a single request larger than the whole
    /// per-GPU cap, which is admitted onto an empty GPU (blocking would
    /// deadlock) and counted here. The CI overload gate requires 0.
    pub kv_violations: u64,
}

#[derive(Clone, Copy, Debug)]
struct Active {
    req: usize,
    prefill_left: u32,
    iters_left: u32,
    first_token_done: bool,
}

struct AGpu {
    /// Busy slots, densely packed (slot identity is immaterial).
    active: Vec<Active>,
    n_slots: u32,
    iterating: bool,
    draining: bool,
    alive: bool,
    t_iter: f64,
    /// Crashed / preempted / in an outage: still provisioned (and billed —
    /// a rebooting machine is not returned to the provider) but not
    /// serving. Distinct from `!alive`, which means retired for good.
    down: bool,
    /// Bumped on every kill; events stamped with an older generation are
    /// stale and ignored (a pending iteration or crash that outlived the
    /// GPU state it was scheduled against). Always 0 with faults off.
    gen: u32,
    /// This GPU's independent failure stream (chaos runs only).
    frng: Option<Rng>,
    /// Repair time / classification of the next drawn failure.
    fail_mttr: f64,
    fail_preempt: bool,
    /// KV tokens reserved by in-flight requests (full-residency
    /// `l_in + l_out` reservations; always 0 with KV bookkeeping off).
    kv_reserved: u64,
}

impl AGpu {
    fn new(n_slots: u32, t_iter: f64) -> Self {
        AGpu {
            active: Vec::with_capacity(n_slots as usize),
            n_slots,
            iterating: false,
            draining: false,
            alive: true,
            t_iter,
            down: false,
            gen: 0,
            frng: None,
            fail_mttr: 0.0,
            fail_preempt: false,
            kv_reserved: 0,
        }
    }

    fn n_busy(&self) -> u32 {
        self.active.len() as u32
    }

    fn free_slots(&self) -> u32 {
        self.n_slots - self.n_busy()
    }
}

struct Tier {
    queue: VecDeque<usize>,
    gpus: Vec<AGpu>,
    /// Admitting candidates (alive, not draining, not iterating — which
    /// by the loop invariant means idle; see `fleetsim::idle`). Kept in
    /// sync via [`Tier::sync_idle`] after every per-GPU state change.
    idle: IdleSet,
    /// Provisioned (alive) GPUs, including draining ones — they still run.
    n_alive: u64,
    /// Sum of slots across alive GPUs.
    prov_slots: u64,
    /// Busy slots across alive GPUs.
    busy_slots: u64,
    /// Scale-ups scheduled but not yet materialized (gross).
    pending: u64,
    /// Of `pending`, how many to discard on arrival (scale-down overtook
    /// an in-flight scale-up; provisioning events cannot be recalled).
    cancel: u64,
    /// Controller target after the latest replan.
    target: u64,
    /// Slot count / price / SLO for *newly provisioned* GPUs (changes on
    /// a layout switch).
    n_slots_cfg: u32,
    cost_hr: f64,
    slo_s: f64,
    /// Queue-wait budget the epoch SLO check compares against — derived
    /// from the current plan's calibrated service stats exactly as
    /// `planner::sizing::min_gpus` derives its feasibility budget (Eq. 8,
    /// falling back to the pure-wait SLO when prefill alone exceeds it).
    wait_budget_s: f64,
    // Piecewise-constant integrals, epoch-local and whole-run.
    last_t: f64,
    busy_acc: f64,
    prov_acc: f64,
    gpu_acc: f64,
    gpu_total: f64,
    // Epoch-local counters (streaming digests — reset, never reallocated).
    ttft_epoch: EpochDigest,
    wait_epoch: EpochDigest,
    completed_epoch: u64,
    arrivals_epoch: u64,
    // Whole-run counters.
    completed_total: u64,
    arrivals_total: u64,
    /// Nested-outage depth (> 0 while a scheduled whole-tier outage is in
    /// force; per-GPU restores are deferred until it lifts).
    outage_depth: u32,
    /// Whether this tier's SKU is spot-preemptible (chaos runs draw
    /// preemption events only against preemptible tiers).
    preemptible: bool,
    /// Per-GPU KV token capacity (None = no KV bookkeeping). Re-derived
    /// from the tier spec on every layout switch.
    kv_cap: Option<u64>,
    /// KV tokens the queued (not yet admitted) requests will reserve —
    /// the "projected" part of the admission watermark's occupancy.
    kv_queued: u64,
    /// Head-of-line admissions blocked on the KV gate.
    kv_blocked: u64,
    /// Oversized reservations admitted past the cap (see
    /// [`AutoscaleReport::kv_violations`]).
    kv_violations: u64,
}

impl Tier {
    fn new(
        n0: u64,
        n_slots: u32,
        t_iter: f64,
        cost_hr: f64,
        slo_s: f64,
        wait_budget_s: f64,
    ) -> Self {
        let mut idle = IdleSet::new();
        idle.reset(n0 as usize);
        for gi in 0..n0 as usize {
            idle.insert(gi);
        }
        Tier {
            queue: VecDeque::new(),
            gpus: (0..n0).map(|_| AGpu::new(n_slots, t_iter)).collect(),
            idle,
            n_alive: n0,
            prov_slots: n0 * n_slots as u64,
            busy_slots: 0,
            pending: 0,
            cancel: 0,
            target: n0,
            n_slots_cfg: n_slots,
            cost_hr,
            slo_s,
            wait_budget_s,
            last_t: 0.0,
            busy_acc: 0.0,
            prov_acc: 0.0,
            gpu_acc: 0.0,
            gpu_total: 0.0,
            ttft_epoch: EpochDigest::new(),
            wait_epoch: EpochDigest::new(),
            completed_epoch: 0,
            arrivals_epoch: 0,
            completed_total: 0,
            arrivals_total: 0,
            outage_depth: 0,
            preemptible: false,
            kv_cap: None,
            kv_queued: 0,
            kv_blocked: 0,
            kv_violations: 0,
        }
    }

    /// Advance the piecewise-constant integrals to `t`. Must run before
    /// any capacity/occupancy change at `t`.
    fn integrate(&mut self, t: f64) {
        if t <= self.last_t {
            return;
        }
        let dt = t - self.last_t;
        self.busy_acc += self.busy_slots as f64 * dt;
        self.prov_acc += self.prov_slots as f64 * dt;
        self.gpu_acc += self.n_alive as f64 * dt;
        self.gpu_total += self.n_alive as f64 * dt;
        self.last_t = t;
    }

    /// Projected KV occupancy: reserved tokens on serving GPUs plus the
    /// queue's outstanding demand, over serving KV capacity. 0.0 with KV
    /// bookkeeping off; 1.0 when the tier has KV demand but no serving
    /// capacity at all (every watermark reads saturated).
    fn kv_occupancy(&self) -> f64 {
        let Some(cap) = self.kv_cap else {
            return 0.0;
        };
        let mut reserved = self.kv_queued;
        let mut n_serving = 0u64;
        for g in &self.gpus {
            if g.alive && !g.down {
                reserved += g.kv_reserved;
                n_serving += 1;
            }
        }
        let denom = n_serving * cap;
        if denom == 0 {
            return if reserved > 0 { 1.0 } else { 0.0 };
        }
        reserved as f64 / denom as f64
    }

    /// Alive GPUs that are accepting work (not draining, not down).
    fn n_active(&self) -> u64 {
        self.gpus
            .iter()
            .filter(|g| g.alive && !g.down && !g.draining)
            .count() as u64
    }

    /// Re-derive GPU `gi`'s membership in the idle (admitting) set —
    /// idempotent, called after any state change touching the GPU.
    fn sync_idle(&mut self, gi: usize) {
        let g = &self.gpus[gi];
        self.idle
            .set(gi, g.alive && !g.down && !g.draining && !g.iterating);
    }

    /// The idle-most admitting GPU, if any (the arrival wake target). All
    /// candidates tie at `n_slots` free slots — a non-iterating GPU is
    /// empty (loop invariant) — so the original strict-`>` scan's "first
    /// maximum" is exactly the lowest idle index.
    fn wake_candidate(&self) -> Option<usize> {
        let gi = self.idle.min();
        if let Some(gi) = gi {
            let g = &self.gpus[gi];
            debug_assert!(
                g.alive && !g.draining && !g.iterating && g.active.is_empty(),
                "idle-set invariant violated for GPU {gi}"
            );
        }
        gi
    }

    /// Admit queued requests onto GPU `gi` while it has free slots,
    /// recording each admission's queue wait.
    fn admit_into(
        &mut self,
        gi: usize,
        t: f64,
        arrival_of: &[f64],
        l_in_routed: &[u32],
        l_out_of: &[u32],
        chunk: u32,
    ) {
        loop {
            {
                let g = &self.gpus[gi];
                if !g.alive || g.down || g.draining || g.free_slots() == 0 {
                    return;
                }
            }
            let Some(&req) = self.queue.front() else {
                return;
            };
            // KV gate (head-of-line, FCFS preserved — no overtaking): the
            // front request reserves its full-residency `l_in + l_out`
            // tokens, or the whole queue waits for completions to free
            // them. An oversized request on an *empty* GPU admits anyway
            // (blocking would deadlock) and trips the violation counter.
            if let Some(cap) = self.kv_cap {
                let need = l_in_routed[req] as u64 + l_out_of[req] as u64;
                if self.gpus[gi].kv_reserved + need > cap {
                    if self.gpus[gi].kv_reserved > 0 {
                        self.kv_blocked += 1;
                        return;
                    }
                    self.kv_violations += 1;
                }
                self.gpus[gi].kv_reserved += need;
                self.kv_queued = self.kv_queued.saturating_sub(need);
            }
            self.queue.pop_front();
            self.wait_epoch.push(t - arrival_of[req]);
            let g = &mut self.gpus[gi];
            let prefill = (l_in_routed[req] as u64).div_ceil(chunk as u64) as u32;
            g.active.push(Active {
                req,
                prefill_left: prefill,
                iters_left: prefill + l_out_of[req],
                first_token_done: false,
            });
            self.busy_slots += 1;
        }
    }

    /// Remove an empty GPU from the fleet (drain completed, or an idle
    /// scale-down victim).
    fn retire(&mut self, gi: usize) {
        let g = &mut self.gpus[gi];
        debug_assert!(g.alive && g.n_busy() == 0, "retiring a busy/dead GPU");
        g.alive = false;
        g.draining = false;
        self.n_alive -= 1;
        self.prov_slots -= g.n_slots as u64;
        self.sync_idle(gi);
    }

    /// Scale down by `count` GPUs: idle victims retire immediately, busy
    /// ones drain (stop admitting, finish in-flight, then retire).
    fn drain(&mut self, count: u64) {
        let mut left = count;
        let idle_victims: Vec<usize> = (0..self.gpus.len())
            .filter(|&i| {
                let g = &self.gpus[i];
                g.alive && !g.draining && g.n_busy() == 0
            })
            .collect();
        for gi in idle_victims {
            if left == 0 {
                return;
            }
            self.retire(gi);
            left -= 1;
        }
        if left > 0 {
            let mut busy: Vec<usize> = (0..self.gpus.len())
                .filter(|&i| {
                    let g = &self.gpus[i];
                    g.alive && !g.draining
                })
                .collect();
            busy.sort_by_key(|&i| self.gpus[i].n_busy());
            for gi in busy {
                if left == 0 {
                    return;
                }
                self.gpus[gi].draining = true;
                self.sync_idle(gi);
                left -= 1;
            }
        }
    }

    /// Take GPU `gi` down: kill its in-flight requests (requeued at the
    /// head of the tier queue in request order, each counted as a retry),
    /// invalidate its pending events via the generation bump, and drop it
    /// from the admitting set. The GPU stays provisioned (and billed)
    /// until restored or retired. A request whose retry count exceeds
    /// `max_retries` is dropped instead of requeued (`None` = unbounded).
    /// Returns the number of kills.
    #[allow(clippy::too_many_arguments)]
    fn take_down(
        &mut self,
        gi: usize,
        retries: &mut [u32],
        l_in_routed: &[u32],
        l_out_of: &[u32],
        max_retries: Option<u32>,
        dropped: &mut u64,
    ) -> u64 {
        let g = &mut self.gpus[gi];
        debug_assert!(g.alive && !g.down, "taking down a dead/down GPU");
        let mut killed: Vec<usize> = g.active.iter().map(|a| a.req).collect();
        g.active.clear();
        g.iterating = false;
        g.gen = g.gen.wrapping_add(1);
        g.down = true;
        g.kv_reserved = 0;
        killed.sort_unstable();
        // push_front in descending request order leaves the queue head at
        // the lowest request index — retried work goes back first-in-line.
        for &req in killed.iter().rev() {
            retries[req] += 1;
            if max_retries.is_some_and(|budget| retries[req] > budget) {
                *dropped += 1;
                continue;
            }
            self.queue.push_front(req);
            if self.kv_cap.is_some() {
                self.kv_queued += l_in_routed[req] as u64 + l_out_of[req] as u64;
            }
        }
        self.busy_slots -= killed.len() as u64;
        self.sync_idle(gi);
        killed.len() as u64
    }
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(usize),
    /// (tier, gpu index, generation) — stale generations are skipped
    /// (the GPU was killed after this iteration was scheduled). The
    /// generation is always 0 with faults off, so the fault-free event
    /// stream is unchanged payload-for-payload.
    Iteration(usize, usize, u32),
    /// (tier, GPU count) — scale-up materializing after the delay.
    Provision(usize, u64),
    Epoch,
    /// (tier, gpu, generation) — a drawn crash/preemption strikes. Never
    /// scheduled with faults off.
    Crash(usize, usize, u32),
    /// (tier, gpu, generation) — a killed GPU rejoins after its repair
    /// time plus the provisioning (cold-start) delay.
    Restore(usize, usize, u32),
    /// Scheduled whole-tier outage window opens / closes.
    OutageStart(usize),
    OutageEnd(usize),
    /// A deferred arrival re-entering admission after its deadline.
    /// Never scheduled with admission control off.
    AdmitRetry(usize),
}

/// The queue-wait budget a tier's SLO check compares against — the exact
/// Eq. 8 budget `T_slo - T_prefill^(99) - t_iter` when non-negative, else
/// the pure-wait fallback (`planner::sizing`'s paper-consistency note:
/// prefill alone can exceed the SLO at dense slot counts, and sizing is
/// then rho_max-dominated with a wait-only SLO).
fn wait_budget_s(slo_s: f64, svc: &Option<crate::queueing::service::ServiceStats>) -> f64 {
    match svc {
        Some(s) => {
            let b = slo_s - s.p99_prefill_s - s.t_iter_s;
            if b >= 0.0 {
                b
            } else {
                slo_s
            }
        }
        None => slo_s,
    }
}

/// Schedule a controller event through the checked path: an event aimed
/// at the past is re-scheduled at the current time and counted — the
/// real error path replacing the release-stripped `debug_assert`.
fn schedule_logged(events: &mut EventQueue<Ev>, time: f64, ev: Ev, time_travel: &mut u64) {
    if let Err(e) = events.schedule_checked(time, ev) {
        *time_travel += 1;
        events.schedule(e.now, ev);
    }
}

fn maybe_schedule_iteration(
    tiers: &mut [Tier],
    events: &mut EventQueue<Ev>,
    t: f64,
    ti: usize,
    gi: usize,
) {
    let (alive, busy, iterating, t_iter, gen) = {
        let g = &tiers[ti].gpus[gi];
        (
            g.alive && !g.down,
            g.n_busy(),
            g.iterating,
            g.t_iter,
            g.gen,
        )
    };
    if alive && busy > 0 && !iterating {
        tiers[ti].gpus[gi].iterating = true;
        events.schedule(t + t_iter, Ev::Iteration(ti, gi, gen));
    }
    tiers[ti].sync_idle(gi);
}

/// Draw GPU `gi`'s next failure from its seeded stream and schedule the
/// crash, creating the stream on first touch. No-op when no failure
/// process applies to this tier.
fn arm_gpu_fault(
    tier: &mut Tier,
    events: &mut EventQueue<Ev>,
    t: f64,
    ti: usize,
    gi: usize,
    fp: &FaultPlan,
    time_travel: &mut u64,
) {
    let preemptible = tier.preemptible;
    let g = &mut tier.gpus[gi];
    if g.frng.is_none() {
        g.frng = Some(fp.gpu_rng(ti, gi as u64));
    }
    let rng = g.frng.as_mut().expect("just set");
    let Some(d) = fp.draw(rng, preemptible) else {
        return;
    };
    g.fail_mttr = d.mttr_s;
    g.fail_preempt = d.preemption;
    let gen = g.gen;
    schedule_logged(events, t + d.dt_s, Ev::Crash(ti, gi, gen), time_travel);
}

/// Re-derive the failover state and the effective routing vectors after a
/// capacity or boundary change. `None` in `eff` means "route on the
/// planned vectors" — the case whenever failover is disabled or every
/// tier is healthy, so an armed-but-never-engaged failover routes
/// bit-identically to no failover at all.
#[allow(clippy::type_complexity)]
fn refresh_failover(
    tiers: &[Tier],
    boundaries: &[u32],
    gammas: &[f64],
    fo: Option<&FailoverConfig>,
    st: &mut FailoverState,
    eff: &mut Option<(Vec<u32>, Vec<f64>, Vec<usize>)>,
) {
    let Some(cfg) = fo else {
        return;
    };
    let mut any = false;
    for (ti, tier) in tiers.iter().enumerate() {
        any |= st.observe(ti, tier.n_active(), tier.target.max(1), cfg);
    }
    *eff = any.then(|| effective_routes(boundaries, gammas, st.degraded(), cfg.gamma_boost));
}

/// Rescale the fleet to a freshly adopted plan. Routing flips to the new
/// boundaries/gammas immediately — that part is software (the paper's
/// claim). Hardware follows: a tier whose slot shape changed is replaced
/// rolling-style (cancel incoming capacity, drain every live GPU,
/// provision the new counts after the cold-start delay); a tier whose
/// window is unchanged — including every pure-gamma switch — just
/// resizes. Requests already queued under the old layout are not
/// re-routed; they finish on draining capacity or the incoming fleet.
#[allow(clippy::too_many_arguments)]
fn apply_scaling(
    tiers: &mut [Tier],
    events: &mut EventQueue<Ev>,
    t: f64,
    cfg: &AutoscaleConfig,
    plan: &TieredPlan,
    switched: bool,
    boundaries: &mut Vec<u32>,
    gammas: &mut Vec<f64>,
    slo_default_s: f64,
    time_travel: &mut u64,
    kv: Option<KvPlanPolicy>,
) {
    if switched {
        *boundaries = plan.boundaries();
        *gammas = plan.gammas.clone();
    }
    for (ti, tier) in tiers.iter_mut().enumerate() {
        let spec_t = &plan.spec.tiers[ti];
        let target = plan.tiers[ti].n_gpus.max(cfg.min_gpus_per_tier);
        tier.target = target;
        if switched {
            tier.slo_s = spec_t.slo_or(slo_default_s);
            tier.cost_hr = spec_t.cost_hr;
            tier.preemptible = spec_t.sku.is_some_and(|s| s.preemptible);
            // The per-GPU KV cap follows the tier's slot shape.
            tier.kv_cap = kv.map(|p| p.cap_tokens(spec_t.n_max, spec_t.c_max));
        }
        // Re-derive the epoch SLO's wait budget from this replan's
        // calibration (the residual distribution shifts with gamma).
        tier.wait_budget_s = wait_budget_s(tier.slo_s, &plan.tiers[ti].svc);
        // A switch that leaves this tier's slot shape intact (a pure
        // gamma/routing change — software) is just a resize; only a
        // changed window forces the hardware replacement below.
        let hw_changed = switched && spec_t.n_max != tier.n_slots_cfg;
        if hw_changed {
            tier.cancel = tier.pending;
            let live: Vec<usize> = (0..tier.gpus.len())
                .filter(|&i| {
                    let g = &tier.gpus[i];
                    g.alive && !g.draining
                })
                .collect();
            for gi in live {
                if tier.gpus[gi].n_busy() == 0 {
                    tier.retire(gi);
                } else {
                    tier.gpus[gi].draining = true;
                    tier.sync_idle(gi);
                }
            }
            tier.n_slots_cfg = spec_t.n_max;
            tier.pending += target;
            schedule_logged(
                events,
                t + cfg.provision_delay_s,
                Ev::Provision(ti, target),
                time_travel,
            );
        } else {
            let avail = tier.n_active() + (tier.pending - tier.cancel);
            match target.cmp(&avail) {
                std::cmp::Ordering::Greater => {
                    let add = target - avail;
                    tier.pending += add;
                    schedule_logged(
                        events,
                        t + cfg.provision_delay_s,
                        Ev::Provision(ti, add),
                        time_travel,
                    );
                }
                std::cmp::Ordering::Less => {
                    let mut excess = avail - target;
                    let cancel_add = excess.min(tier.pending - tier.cancel);
                    tier.cancel += cancel_add;
                    excess -= cancel_add;
                    if excess > 0 {
                        tier.drain(excess);
                    }
                }
                std::cmp::Ordering::Equal => {}
            }
        }
    }
}

/// Close the current epoch: snapshot per-tier metrics, reset the
/// epoch-local accumulators. `tiers` must already be integrated to `t`.
fn record_epoch(
    tiers: &mut [Tier],
    epoch: usize,
    t_start: f64,
    t: f64,
    lambda_est: f64,
    switched: bool,
) -> EpochMetrics {
    let dur = (t - t_start).max(1e-12);
    let arrivals: u64 = tiers.iter().map(|x| x.arrivals_epoch).sum();
    let mut slo_ok = true;
    let mut rows = Vec::with_capacity(tiers.len());
    let mut gpu_hours = 0.0;
    let mut cost = 0.0;
    for tier in tiers.iter_mut() {
        let util = if tier.prov_acc > 0.0 {
            tier.busy_acc / tier.prov_acc
        } else {
            0.0
        };
        // Streaming P² P99s (0.0 when the epoch saw no samples).
        let p99 = tier.ttft_epoch.p99();
        let wait_p99 = tier.wait_epoch.p99();
        // The sizing-consistent SLO check: P99 queue wait against the
        // Eq. 8 budget (see `wait_budget_s`); raw TTFT includes physical
        // prefill, which at dense slot counts exceeds the SLO by itself.
        if !tier.wait_epoch.is_empty() && wait_p99 > tier.wait_budget_s {
            slo_ok = false;
        }
        gpu_hours += tier.gpu_acc / 3600.0;
        cost += tier.gpu_acc / 3600.0 * tier.cost_hr;
        rows.push(EpochTierMetrics {
            n_gpus: tier.n_alive,
            target_gpus: tier.target,
            utilization: util,
            ttft_p99_s: p99,
            wait_p99_s: wait_p99,
            completed: tier.completed_epoch,
            arrivals: tier.arrivals_epoch,
            in_flight: tier.arrivals_total - tier.completed_total,
        });
        tier.busy_acc = 0.0;
        tier.prov_acc = 0.0;
        tier.gpu_acc = 0.0;
        tier.ttft_epoch.reset();
        tier.wait_epoch.reset();
        tier.completed_epoch = 0;
        tier.arrivals_epoch = 0;
    }
    EpochMetrics {
        epoch,
        t_start_s: t_start,
        t_end_s: t,
        lambda_est,
        lambda_realized: arrivals as f64 / dur,
        gpu_hours,
        cost,
        slo_ok,
        switched_layout: switched,
        tiers: rows,
    }
}

/// Simulate `n` requests from a nonstationary arrival `model` through an
/// autoscaled K-tier fleet seeded with `initial`. `input` supplies the
/// planner template (SLO, GPU profile, planner grid) the controller
/// re-plans with; its workload is only a template — each epoch the CDF is
/// re-estimated from the sliding window.
///
/// With a [`RateModel::Constant`] and the same seed, the generated request
/// stream and the per-tier routing are bit-identical to
/// `route_trace_tiered(w, lambda, n, ..)` (tested).
pub fn simulate_autoscale(
    w: &Workload,
    model: RateModel,
    n: usize,
    input: &PlanInput,
    initial: TieredPlan,
    cfg: &AutoscaleConfig,
    seed: u64,
) -> AutoscaleReport {
    simulate_autoscale_chaos(w, model, n, input, initial, cfg, seed, &ChaosOpts::default())
}

/// [`simulate_autoscale_chaos`] with decode-phase KV-cache modeling and
/// stability-guarded admission control (see [`KvFleetOpts`]). With the
/// default opts this *is* `simulate_autoscale_chaos`, bit for bit: no
/// reservation is ever taken, no occupancy observed, no retry event
/// scheduled.
///
/// With a cap: every admitted request reserves `l_in + l_out` KV tokens
/// on its GPU for its full residency; admission blocks head-of-line when
/// the reservation would not fit (requests queue rather than
/// oversubscribe — KV violations are impossible by construction, modulo
/// a single request larger than the whole per-GPU cap). With admission
/// control on top, each arrival is held against its target tier's
/// projected occupancy and escalates engage-side through compress-harder
/// (gamma-tightened ladder), defer-with-deadline, and shed as the last
/// resort — 429-style accounting in [`AutoscaleReport::admit`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_autoscale_kv(
    w: &Workload,
    model: RateModel,
    n: usize,
    input: &PlanInput,
    initial: TieredPlan,
    cfg: &AutoscaleConfig,
    seed: u64,
    chaos: &ChaosOpts,
    kv: &KvFleetOpts,
) -> AutoscaleReport {
    simulate_autoscale_impl(w, model, n, input, initial, cfg, seed, chaos, kv)
}

/// [`simulate_autoscale`] with failure injection and failover response
/// (see [`ChaosOpts`]). With the default opts this *is*
/// `simulate_autoscale`, bit for bit: no fault event is ever scheduled,
/// every generation stamp stays 0, and routing never leaves the planned
/// boundaries.
///
/// Under a fault plan: a crash/preemption/outage kills the victim GPU's
/// in-flight requests (requeued at the head of their tier queue with a
/// retry count — conservation holds exactly: every request completes once
/// and `retries_total == killed_in_flight`), the machine stays billed
/// while down, and a restore pays the drawn repair time *plus* the
/// provisioning cold-start delay before serving again. With failover on,
/// arrivals route on the degraded effective ladder
/// ([`crate::router::failover::effective_routes`]) while any tier sits
/// below its watermark, with hysteresis on recovery.
///
/// A negative `provision_delay_s` is deliberately *not* rejected here:
/// it aims controller events into the past, which the checked scheduler
/// refuses and re-files at the current time, incrementing
/// [`AutoscaleReport::time_travel_events`] — the unit-testable error
/// path the CLI and CI gate on (the CLI validates user input separately).
#[allow(clippy::too_many_arguments)]
pub fn simulate_autoscale_chaos(
    w: &Workload,
    model: RateModel,
    n: usize,
    input: &PlanInput,
    initial: TieredPlan,
    cfg: &AutoscaleConfig,
    seed: u64,
    chaos: &ChaosOpts,
) -> AutoscaleReport {
    simulate_autoscale_impl(
        w,
        model,
        n,
        input,
        initial,
        cfg,
        seed,
        chaos,
        &KvFleetOpts::default(),
    )
}

#[allow(clippy::too_many_arguments)]
fn simulate_autoscale_impl(
    w: &Workload,
    model: RateModel,
    n: usize,
    input: &PlanInput,
    initial: TieredPlan,
    cfg: &AutoscaleConfig,
    seed: u64,
    chaos: &ChaosOpts,
    kv: &KvFleetOpts,
) -> AutoscaleReport {
    assert!(n > 0, "need at least one request");
    assert!(cfg.epoch_s > 0.0 && cfg.window_s > 0.0);
    assert!(
        cfg.min_gpus_per_tier >= 1,
        "a zero-GPU tier floor can starve queued traffic"
    );
    let k = initial.k();
    assert!(k >= 2);
    let kv_policy = kv.cap_frac.map(|f| {
        assert!(
            f.is_finite() && f > 0.0 && f <= 1.0,
            "kv cap_frac must be inside (0, 1], got {f}"
        );
        KvPlanPolicy { cap_frac: f }
    });
    let admit_cfg = kv.admit;
    if let Some(a) = &admit_cfg {
        a.validate().expect("invalid admission config");
    }

    // Trace: seeded exactly like `route_trace_tiered` so the stationary
    // projection routes bit-identically.
    let mut arr = NonstationaryArrivals::new(model, seed);
    let mut rng = Rng::new(seed ^ 0xF1EE7);
    let requests: Vec<Request> = (0..n)
        .map(|i| {
            let t = arr.next_arrival();
            w.sample_request(i as u64, t, &mut rng)
        })
        .collect();
    let l_out_of: Vec<u32> = requests.iter().map(|r| r.l_out).collect();
    let arrival_of: Vec<f64> = requests.iter().map(|r| r.arrival_s).collect();
    let mut l_in_routed: Vec<u32> = vec![0; n];

    let gpu_prof = input.gpu.clone();
    let chunk = gpu_prof.chunk;
    let mut boundaries = initial.boundaries();
    let mut gammas = initial.gammas.clone();
    let mut tiers: Vec<Tier> = initial
        .tiers
        .iter()
        .zip(&initial.spec.tiers)
        .map(|(pool, ts)| {
            let n0 = pool.n_gpus.max(cfg.min_gpus_per_tier);
            let slo = ts.slo_or(input.slo.p99_ttft_s);
            let mut tier = Tier::new(
                n0,
                ts.n_max,
                gpu_prof.t_iter_s(ts.n_max),
                ts.cost_hr,
                slo,
                wait_budget_s(slo, &pool.svc),
            );
            tier.preemptible = ts.sku.is_some_and(|s| s.preemptible);
            tier.kv_cap = kv_policy.map(|p| p.cap_tokens(ts.n_max, ts.c_max));
            tier
        })
        .collect();

    let mut events: EventQueue<Ev> = EventQueue::new();
    let mut time_travel = 0u64;
    for (i, r) in requests.iter().enumerate() {
        events.schedule(r.arrival_s, Ev::Arrival(i));
    }
    schedule_logged(&mut events, cfg.epoch_s, Ev::Epoch, &mut time_travel);

    // Chaos wiring: arm every initial GPU's failure stream and schedule
    // the planned outage windows. None of this runs with faults off, so
    // the event sequence (and hence every tie-break) is unchanged.
    let faults = chaos.faults.as_ref();
    let fo_cfg = chaos.failover.as_ref();
    let mut fo_state = FailoverState::new(k);
    let mut eff: Option<(Vec<u32>, Vec<f64>, Vec<usize>)> = None;
    let mut retries: Vec<u32> = vec![0; n];
    let mut crashes = 0u64;
    let mut preemptions = 0u64;
    let mut killed_in_flight = 0u64;
    let mut spilled = 0u64;
    if let Some(fp) = faults {
        for ti in 0..tiers.len() {
            for gi in 0..tiers[ti].gpus.len() {
                arm_gpu_fault(&mut tiers[ti], &mut events, 0.0, ti, gi, fp, &mut time_travel);
            }
        }
        for o in &fp.outages {
            if o.tier < k {
                schedule_logged(
                    &mut events,
                    o.start_s,
                    Ev::OutageStart(o.tier),
                    &mut time_travel,
                );
                schedule_logged(
                    &mut events,
                    o.start_s + o.duration_s,
                    Ev::OutageEnd(o.tier),
                    &mut time_travel,
                );
            }
        }
    }

    let mut estimator = OnlineEstimator::new(cfg.window_s);
    let mut seasonal = cfg.seasonal_period_s.map(|p| SeasonalEstimator::new(p, 16));
    let mut replanner = Replanner::new(cfg.replan.clone(), initial);
    let mut admit_state = AdmitState::default();
    let mut admit_counters = AdmitCounters::default();
    // Per-request defer counts, allocated only when admission is on.
    let mut defers: Vec<u32> = if admit_cfg.is_some() { vec![0; n] } else { Vec::new() };
    let mut shed_total = 0u64;
    let mut dropped_total = 0u64;
    let mut done = vec![false; n];
    let mut completed_total = 0u64;
    let mut n_compressed = 0u64;
    let mut layout_switches = 0u64;
    let mut epochs: Vec<EpochMetrics> = Vec::new();
    let mut epoch_start = 0.0;
    let mut epoch_idx = 0usize;
    let mut t_last = 0.0;

    while let Some((t, ev)) = events.pop() {
        if completed_total + shed_total + dropped_total == n as u64 {
            // All work done: trailing controller/provision/fault events
            // are inert (capacity added after the horizon would cost
            // money for no traffic — and a crash-restore cycle with no
            // traffic left would never terminate).
            match ev {
                Ev::Epoch
                | Ev::Provision(..)
                | Ev::Crash(..)
                | Ev::Restore(..)
                | Ev::OutageStart(_)
                | Ev::OutageEnd(_) => continue,
                _ => {}
            }
        }
        t_last = t;
        match ev {
            Ev::Arrival(i) | Ev::AdmitRetry(i) => {
                let is_first = matches!(ev, Ev::Arrival(_));
                if is_first {
                    estimator.observe(t, requests[i].l_total);
                }
                let r = &requests[i];
                let (mut ti, mut l_in, mut comp) = match &eff {
                    // Degraded ladder in force: route on the effective
                    // vectors, map back to the physical tier, and count
                    // the spill against what the healthy ladder would
                    // have chosen.
                    Some((eb, eg, map)) => {
                        let (eti, l_in, comp) = crate::fleetsim::fleet::route_request(
                            r.l_total,
                            r.l_in,
                            r.l_out,
                            r.category.compressible(),
                            eb,
                            eg,
                        );
                        let ti = map[eti];
                        let (oti, _, _) = crate::fleetsim::fleet::route_request(
                            r.l_total,
                            r.l_in,
                            r.l_out,
                            r.category.compressible(),
                            &boundaries,
                            &gammas,
                        );
                        if oti != ti && is_first {
                            spilled += 1;
                        }
                        (ti, l_in, comp)
                    }
                    None => crate::fleetsim::fleet::route_request(
                        r.l_total,
                        r.l_in,
                        r.l_out,
                        r.category.compressible(),
                        &boundaries,
                        &gammas,
                    ),
                };
                // Stability-guarded admission: hold the arrival against
                // its target tier's projected KV occupancy and escalate
                // engage-side through the paper-ordered ladder. Off
                // (`admit: None`), the arrival takes the exact
                // pre-admission path above.
                if let Some(acfg) = &admit_cfg {
                    let occ = tiers[ti].kv_occupancy();
                    let engaged = admit_state.observe(ti, occ, acfg);
                    let defers_used = defers[i];
                    // Compress-harder is terminal (it admits into a
                    // tightened band), so it is attempted at most once.
                    let can_recompress = defers_used == 0
                        && acfg.gamma_tighten > 1.0
                        && r.category.compressible();
                    match decide(engaged, can_recompress, defers_used, acfg) {
                        AdmitDecision::Admit => admit_counters.admitted += 1,
                        AdmitDecision::Recompress => {
                            admit_counters.recompressed += 1;
                            // Re-route on the gamma-tightened ladder the
                            // arrival would otherwise have used.
                            let (eb, eg): (&[u32], &[f64]) = match &eff {
                                Some((eb, eg, _)) => (eb, eg),
                                None => (&boundaries, &gammas),
                            };
                            let tg = tightened_gammas(eg, acfg.gamma_tighten);
                            let (nti, nl_in, ncomp) = crate::fleetsim::fleet::route_request(
                                r.l_total,
                                r.l_in,
                                r.l_out,
                                true,
                                eb,
                                &tg,
                            );
                            ti = match &eff {
                                Some((_, _, map)) => map[nti],
                                None => nti,
                            };
                            l_in = nl_in;
                            comp = ncomp;
                        }
                        AdmitDecision::Defer => {
                            admit_counters.deferred += 1;
                            defers[i] += 1;
                            schedule_logged(
                                &mut events,
                                t + acfg.defer_s,
                                Ev::AdmitRetry(i),
                                &mut time_travel,
                            );
                            continue;
                        }
                        AdmitDecision::Shed => {
                            admit_counters.shed += 1;
                            shed_total += 1;
                            continue;
                        }
                    }
                }
                l_in_routed[i] = l_in;
                if comp {
                    n_compressed += 1;
                }
                let wake = {
                    let tier = &mut tiers[ti];
                    tier.integrate(t);
                    tier.arrivals_epoch += 1;
                    tier.arrivals_total += 1;
                    if tier.kv_cap.is_some() {
                        tier.kv_queued += l_in as u64 + l_out_of[i] as u64;
                    }
                    tier.queue.push_back(i);
                    tier.wake_candidate()
                };
                if let Some(gi) = wake {
                    tiers[ti].admit_into(gi, t, &arrival_of, &l_in_routed, &l_out_of, chunk);
                    maybe_schedule_iteration(&mut tiers, &mut events, t, ti, gi);
                }
            }
            Ev::Iteration(ti, gi, gen) => {
                if tiers[ti].gpus[gi].gen != gen {
                    // Scheduled against a GPU state a kill invalidated.
                    continue;
                }
                let tier = &mut tiers[ti];
                tier.integrate(t);
                let gpu = &mut tier.gpus[gi];
                gpu.iterating = false;
                // Advance every busy slot by one lockstep iteration
                // (exactly `fleetsim::sim`'s model; dense slab, swap-
                // remove on completion — slot order is immaterial).
                let mut s = 0;
                while s < gpu.active.len() {
                    let a = &mut gpu.active[s];
                    a.iters_left -= 1;
                    if a.prefill_left > 0 {
                        a.prefill_left -= 1;
                    } else if !a.first_token_done {
                        a.first_token_done = true;
                        tier.ttft_epoch.push(t - requests[a.req].arrival_s);
                    }
                    if a.iters_left == 0 {
                        let req = a.req;
                        if !a.first_token_done {
                            // Degenerate L_out: first token == last.
                            tier.ttft_epoch.push(t - requests[req].arrival_s);
                        }
                        assert!(!done[req], "request {req} completed twice");
                        done[req] = true;
                        gpu.active.swap_remove(s);
                        if tier.kv_cap.is_some() {
                            // Release the full-residency KV reservation.
                            gpu.kv_reserved = gpu
                                .kv_reserved
                                .saturating_sub(l_in_routed[req] as u64 + l_out_of[req] as u64);
                        }
                        completed_total += 1;
                        tier.completed_epoch += 1;
                        tier.completed_total += 1;
                        tier.busy_slots -= 1;
                    } else {
                        s += 1;
                    }
                }
                let (draining, busy) = {
                    let g = &tiers[ti].gpus[gi];
                    (g.draining, g.n_busy())
                };
                if draining {
                    if busy == 0 {
                        tiers[ti].retire(gi);
                    }
                } else {
                    tiers[ti].admit_into(gi, t, &arrival_of, &l_in_routed, &l_out_of, chunk);
                }
                maybe_schedule_iteration(&mut tiers, &mut events, t, ti, gi);
            }
            Ev::Provision(ti, count) => {
                let added = {
                    let tier = &mut tiers[ti];
                    tier.integrate(t);
                    let cancelled = tier.cancel.min(count);
                    tier.cancel -= cancelled;
                    tier.pending -= count;
                    let real = count - cancelled;
                    for _ in 0..real {
                        let t_iter = gpu_prof.t_iter_s(tier.n_slots_cfg);
                        tier.gpus.push(AGpu::new(tier.n_slots_cfg, t_iter));
                        tier.n_alive += 1;
                        tier.prov_slots += tier.n_slots_cfg as u64;
                    }
                    real as usize
                };
                let len = tiers[ti].gpus.len();
                for gi in len - added..len {
                    if tiers[ti].outage_depth > 0 {
                        // Born into a tier-wide outage: provisioned (and
                        // billed) but down until the window lifts.
                        tiers[ti].gpus[gi].down = true;
                        tiers[ti].sync_idle(gi);
                        continue;
                    }
                    if let Some(fp) = faults {
                        arm_gpu_fault(&mut tiers[ti], &mut events, t, ti, gi, fp, &mut time_travel);
                    }
                    tiers[ti].admit_into(gi, t, &arrival_of, &l_in_routed, &l_out_of, chunk);
                    maybe_schedule_iteration(&mut tiers, &mut events, t, ti, gi);
                }
                refresh_failover(&tiers, &boundaries, &gammas, fo_cfg, &mut fo_state, &mut eff);
            }
            Ev::Crash(ti, gi, gen) => {
                let (alive, down, cur_gen, draining, preempt, mttr) = {
                    let g = &tiers[ti].gpus[gi];
                    (g.alive, g.down, g.gen, g.draining, g.fail_preempt, g.fail_mttr)
                };
                if !alive || down || cur_gen != gen {
                    // A retire, an earlier kill, or an outage beat us here.
                    continue;
                }
                if preempt {
                    preemptions += 1;
                } else {
                    crashes += 1;
                }
                tiers[ti].integrate(t);
                killed_in_flight += tiers[ti].take_down(
                    gi,
                    &mut retries,
                    &l_in_routed,
                    &l_out_of,
                    cfg.max_retries,
                    &mut dropped_total,
                );
                if draining {
                    // The scale-down victim died before draining: it can
                    // retire on the spot, nothing left to serve out.
                    tiers[ti].gpus[gi].down = false;
                    tiers[ti].retire(gi);
                } else if tiers[ti].outage_depth == 0 {
                    // Restart pays the repair time plus the same cold
                    // provisioning delay as a fresh scale-up. During an
                    // outage the tier-wide OutageEnd revives instead.
                    let new_gen = tiers[ti].gpus[gi].gen;
                    schedule_logged(
                        &mut events,
                        t + mttr + cfg.provision_delay_s,
                        Ev::Restore(ti, gi, new_gen),
                        &mut time_travel,
                    );
                }
                // The kill may have stranded requeued work while other
                // GPUs sit idle (idle GPUs are only woken by arrivals):
                // wake them now.
                while !tiers[ti].queue.is_empty() {
                    let Some(wi) = tiers[ti].wake_candidate() else {
                        break;
                    };
                    tiers[ti].admit_into(wi, t, &arrival_of, &l_in_routed, &l_out_of, chunk);
                    if tiers[ti].gpus[wi].n_busy() == 0 {
                        break;
                    }
                    maybe_schedule_iteration(&mut tiers, &mut events, t, ti, wi);
                }
                refresh_failover(&tiers, &boundaries, &gammas, fo_cfg, &mut fo_state, &mut eff);
            }
            Ev::Restore(ti, gi, gen) => {
                let (alive, down, cur_gen, draining) = {
                    let g = &tiers[ti].gpus[gi];
                    (g.alive, g.down, g.gen, g.draining)
                };
                if !alive || !down || cur_gen != gen {
                    continue;
                }
                if tiers[ti].outage_depth > 0 {
                    // Personal restore landing inside a tier-wide outage
                    // window defers to OutageEnd's mass revive.
                    continue;
                }
                tiers[ti].integrate(t);
                tiers[ti].gpus[gi].down = false;
                if draining {
                    // Marked for scale-down while it was dead: it comes
                    // back empty, so it retires immediately.
                    tiers[ti].retire(gi);
                } else {
                    tiers[ti].sync_idle(gi);
                    if let Some(fp) = faults {
                        arm_gpu_fault(&mut tiers[ti], &mut events, t, ti, gi, fp, &mut time_travel);
                    }
                    tiers[ti].admit_into(gi, t, &arrival_of, &l_in_routed, &l_out_of, chunk);
                    maybe_schedule_iteration(&mut tiers, &mut events, t, ti, gi);
                }
                refresh_failover(&tiers, &boundaries, &gammas, fo_cfg, &mut fo_state, &mut eff);
            }
            Ev::OutageStart(ti) => {
                tiers[ti].outage_depth += 1;
                if tiers[ti].outage_depth == 1 {
                    tiers[ti].integrate(t);
                    for gi in 0..tiers[ti].gpus.len() {
                        let (alive, down, draining) = {
                            let g = &tiers[ti].gpus[gi];
                            (g.alive, g.down, g.draining)
                        };
                        if !alive || down {
                            continue;
                        }
                        killed_in_flight += tiers[ti].take_down(
                            gi,
                            &mut retries,
                            &l_in_routed,
                            &l_out_of,
                            cfg.max_retries,
                            &mut dropped_total,
                        );
                        if draining {
                            tiers[ti].gpus[gi].down = false;
                            tiers[ti].retire(gi);
                        }
                    }
                }
                refresh_failover(&tiers, &boundaries, &gammas, fo_cfg, &mut fo_state, &mut eff);
            }
            Ev::OutageEnd(ti) => {
                if tiers[ti].outage_depth > 0 {
                    tiers[ti].outage_depth -= 1;
                }
                if tiers[ti].outage_depth == 0 {
                    tiers[ti].integrate(t);
                    for gi in 0..tiers[ti].gpus.len() {
                        let (alive, down, draining) = {
                            let g = &tiers[ti].gpus[gi];
                            (g.alive, g.down, g.draining)
                        };
                        if !alive || !down {
                            continue;
                        }
                        tiers[ti].gpus[gi].down = false;
                        if draining {
                            tiers[ti].retire(gi);
                            continue;
                        }
                        tiers[ti].sync_idle(gi);
                        if let Some(fp) = faults {
                            arm_gpu_fault(
                                &mut tiers[ti],
                                &mut events,
                                t,
                                ti,
                                gi,
                                fp,
                                &mut time_travel,
                            );
                        }
                        tiers[ti].admit_into(gi, t, &arrival_of, &l_in_routed, &l_out_of, chunk);
                        maybe_schedule_iteration(&mut tiers, &mut events, t, ti, gi);
                    }
                }
                refresh_failover(&tiers, &boundaries, &gammas, fo_cfg, &mut fo_state, &mut eff);
            }
            Ev::Epoch => {
                for tier in tiers.iter_mut() {
                    tier.integrate(t);
                }
                let lambda_est = estimator.rate(t);
                // Plan against the peak-tracking estimate (lag ~W/8 vs
                // ~W/2 for the mean) scaled by the headroom knob: on an
                // upswing, demand keeps growing for provision_delay_s
                // after the decision. With `forecast` on, also anticipate
                // one epoch ahead and take whichever is larger (one
                // buffer pass either way).
                let horizon = cfg.forecast.then_some(cfg.epoch_s);
                let mut lambda_plan =
                    estimator.planning_rate(t, 4, horizon) * cfg.target_headroom;
                // Seasonal (period-aware) anticipation: fold this epoch's
                // windowed rate into its phase bin, then raise the plan to
                // the next epoch's same-phase historical mean if that is
                // larger. First pass through the period has no history and
                // leaves the reactive estimate untouched.
                if let Some(se) = &mut seasonal {
                    se.observe(t, lambda_est);
                    if let Some(f) = se.forecast(t + cfg.epoch_s) {
                        lambda_plan = lambda_plan.max(f * cfg.target_headroom);
                    }
                }
                let mut switched = false;
                if cfg.replanning && lambda_plan > 0.0 {
                    let mut pi = input.clone();
                    pi.lambda = lambda_plan;
                    if let Some(snap) = estimator.snapshot(w) {
                        pi.workload = snap;
                    }
                    if let Ok(out) = replanner.replan(&pi) {
                        switched = out.switched_layout;
                        if switched {
                            layout_switches += 1;
                        }
                        apply_scaling(
                            &mut tiers,
                            &mut events,
                            t,
                            cfg,
                            &out.plan,
                            switched,
                            &mut boundaries,
                            &mut gammas,
                            input.slo.p99_ttft_s,
                            &mut time_travel,
                            kv_policy,
                        );
                        // Boundaries, gammas, and targets may all have
                        // moved; re-derive the failover view against them.
                        refresh_failover(
                            &tiers,
                            &boundaries,
                            &gammas,
                            fo_cfg,
                            &mut fo_state,
                            &mut eff,
                        );
                    }
                }
                epochs.push(record_epoch(
                    &mut tiers,
                    epoch_idx,
                    epoch_start,
                    t,
                    lambda_est,
                    switched,
                ));
                epoch_idx += 1;
                epoch_start = t;
                if completed_total + shed_total + dropped_total < n as u64 {
                    schedule_logged(&mut events, t + cfg.epoch_s, Ev::Epoch, &mut time_travel);
                }
            }
        }
    }

    // Trailing partial epoch (completions after the last Epoch event).
    for tier in tiers.iter_mut() {
        tier.integrate(t_last);
    }
    let has_tail = t_last > epoch_start + 1e-12
        || tiers
            .iter()
            .any(|x| x.arrivals_epoch > 0 || x.completed_epoch > 0);
    if has_tail {
        let lambda_est = estimator.rate(t_last);
        epochs.push(record_epoch(
            &mut tiers,
            epoch_idx,
            epoch_start,
            t_last,
            lambda_est,
            false,
        ));
    }

    // Totals from the epoch records: they partition the run exactly, and
    // each epoch was billed at the tier prices in force *during* it (a
    // layout switch can change a tier's $/hr mid-run).
    let gpu_hours: f64 = epochs.iter().map(|e| e.gpu_hours).sum();
    let cost: f64 = epochs.iter().map(|e| e.cost).sum();
    debug_assert!(
        (gpu_hours - tiers.iter().map(|x| x.gpu_total).sum::<f64>() / 3600.0).abs()
            < 1e-6 * gpu_hours.max(1.0),
        "epoch partition lost GPU-time"
    );
    let slo_ok = epochs.iter().filter(|e| e.slo_ok).count();
    let time_travel_events = time_travel + events.clamped();
    if time_travel_events > 0 {
        eprintln!(
            "warning: autoscale DES clamped {time_travel_events} event(s) scheduled into \
             the past to the current simulation time"
        );
    }
    AutoscaleReport {
        n_total: n as u64,
        completed: completed_total,
        censored: n as u64 - completed_total - shed_total - dropped_total,
        n_compressed,
        gpu_hours,
        cost,
        horizon_s: t_last,
        slo_ok_frac: slo_ok as f64 / epochs.len().max(1) as f64,
        layout_switches,
        final_gpus: tiers.iter().map(|x| x.n_alive).collect(),
        epochs,
        time_travel_events,
        crashes,
        preemptions,
        killed_in_flight,
        retries_total: retries.iter().map(|&r| r as u64).sum(),
        max_retry: retries.iter().copied().max().unwrap_or(0),
        spilled,
        dropped_retries: dropped_total,
        admit: admit_counters,
        kv_blocked: tiers.iter().map(|x| x.kv_blocked).sum(),
        kv_violations: tiers.iter().map(|x| x.kv_violations).sum(),
    }
}
