//! Heuristic content-category classifier (paper §5.2: "the category signal
//! reuses the per-request EMA estimate from the base router at zero
//! additional overhead" — here, a cheap single-pass structural classifier).
//!
//! Code detection is what matters for safety (code must never be
//! compressed); the prose/RAG distinction only tunes the estimator prior.

use crate::workload::request::Category;

/// Single-pass structural features of a prompt.
#[derive(Clone, Copy, Debug, Default)]
pub struct TextFeatures {
    pub len_bytes: usize,
    pub lines: u32,
    pub brace_semicolon: u32,
    pub indent_lines: u32,
    pub code_keywords: u32,
    pub json_punct: u32,
    pub sentences_terminated: u32,
    pub question_marks: u32,
}

const CODE_KEYWORDS: [&str; 14] = [
    "fn ", "def ", "class ", "import ", "return ", "let ", "const ", "var ", "if (",
    "for (", "while (", "#include", "pub fn", "lambda ",
];

pub fn extract_features(text: &str) -> TextFeatures {
    let mut f = TextFeatures {
        len_bytes: text.len(),
        ..Default::default()
    };
    for line in text.lines() {
        f.lines += 1;
        if line.starts_with("    ") || line.starts_with('\t') {
            f.indent_lines += 1;
        }
    }
    for c in text.chars() {
        match c {
            '{' | '}' | ';' => f.brace_semicolon += 1,
            ':' | '[' | ']' | '"' => f.json_punct += 1,
            '.' | '!' => f.sentences_terminated += 1,
            '?' => f.question_marks += 1,
            _ => {}
        }
    }
    for kw in CODE_KEYWORDS {
        f.code_keywords += text.matches(kw).count() as u32;
    }
    f
}

/// Classify a prompt's content category.
pub fn classify(text: &str) -> Category {
    let f = extract_features(text);
    let per_kb = |x: u32| x as f64 * 1024.0 / f.len_bytes.max(1) as f64;

    let code_density = per_kb(f.brace_semicolon);
    let kw_density = per_kb(f.code_keywords);
    // Tool-use payloads first: JSON-ish punctuation (quotes/colons/brackets)
    // dominating, few code keywords, few prose terminators. JSON also has
    // braces, so this must precede the code check.
    if per_kb(f.json_punct) > 60.0
        && kw_density < 1.0
        && per_kb(f.sentences_terminated) < 8.0
    {
        return Category::ToolUse;
    }
    // Code: dense braces/semicolons or code keywords with indentation.
    if code_density > 8.0 || (kw_density > 1.5 && f.indent_lines > 2) {
        return Category::Code;
    }
    // RAG: long multi-paragraph document-like payloads with low question
    // density; conversations are shorter and more interrogative.
    if f.len_bytes > 2048 && per_kb(f.question_marks) < 0.5 {
        return Category::Rag;
    }
    Category::Conversational
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::corpus;
    use crate::util::rng::Rng;

    #[test]
    fn detects_code() {
        let mut rng = Rng::new(1);
        let code = corpus::generate_code(800, &mut rng);
        assert_eq!(classify(&code), Category::Code);
    }

    #[test]
    fn detects_prose_as_rag_when_long() {
        let mut rng = Rng::new(2);
        let doc = corpus::generate_document(
            &corpus::CorpusConfig {
                target_tokens: 2000,
                ..Default::default()
            },
            &mut rng,
        );
        assert_eq!(classify(&doc), Category::Rag);
    }

    #[test]
    fn short_chat_is_conversational() {
        assert_eq!(
            classify("Hey, can you help me plan a trip to Kyoto next spring?"),
            Category::Conversational
        );
    }

    #[test]
    fn json_is_tool_use() {
        let json = r#"{"name": "get_weather", "arguments": {"city": "Paris", "unit": "c"}, "id": "call_1", "extra": ["a", "b", "c"], "nested": {"k": "v"}}"#;
        assert_eq!(classify(json), Category::ToolUse);
    }

    #[test]
    fn code_beats_rag_even_when_long() {
        let mut rng = Rng::new(3);
        let code = corpus::generate_code(4000, &mut rng);
        assert_eq!(classify(&code), Category::Code);
    }

    #[test]
    fn classification_is_gate_safe() {
        // The safety property: generated code must never classify as a
        // compressible category (§5.2).
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let code = corpus::generate_code(200 + rng.below(4000) as u32, &mut rng);
            assert!(!classify(&code).compressible());
        }
    }
}
