//! Token-budget estimation (paper §2.1): `L_total = ceil(|r| / c_hat_k) +
//! max_output_tokens`, where `c_hat_k` is a per-category bytes-per-token
//! EMA updated from post-hoc tokenizer counts.

use crate::workload::request::Category;

/// Per-category bytes-per-token EMA estimator.
#[derive(Clone, Debug)]
pub struct TokenEstimator {
    /// EMA smoothing factor for updates.
    alpha: f64,
    /// c_hat per category, indexed by `idx()`.
    c_hat: [f64; 4],
    /// Update counts (diagnostics).
    updates: [u64; 4],
}

fn idx(c: Category) -> usize {
    match c {
        Category::Conversational => 0,
        Category::Rag => 1,
        Category::Code => 2,
        Category::ToolUse => 3,
    }
}

impl Default for TokenEstimator {
    fn default() -> Self {
        Self::new(0.05)
    }
}

impl TokenEstimator {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        TokenEstimator {
            alpha,
            // Priors: prose ~4.4 B/tok, RAG ~4.2, code ~3.2 (denser symbol
            // mix), tool-use/JSON ~2.8.
            c_hat: [4.4, 4.2, 3.2, 2.8],
            updates: [0; 4],
        }
    }

    /// Estimated prompt tokens for `bytes` of category-`c` content.
    pub fn estimate_prompt_tokens(&self, bytes: usize, c: Category) -> u32 {
        (bytes as f64 / self.c_hat[idx(c)]).ceil().max(1.0) as u32
    }

    /// Estimated total budget L_total (§2.1).
    pub fn estimate_l_total(&self, bytes: usize, max_output: u32, c: Category) -> u32 {
        self.estimate_prompt_tokens(bytes, c) + max_output
    }

    /// Fold an observed (bytes, actual tokens) pair into the EMA.
    pub fn update(&mut self, bytes: usize, actual_tokens: u32, c: Category) {
        if actual_tokens == 0 {
            return;
        }
        let obs = bytes as f64 / actual_tokens as f64;
        let i = idx(c);
        self.c_hat[i] = (1.0 - self.alpha) * self.c_hat[i] + self.alpha * obs;
        self.updates[i] += 1;
    }

    pub fn bytes_per_token(&self, c: Category) -> f64 {
        self.c_hat[idx(c)]
    }

    pub fn update_count(&self, c: Category) -> u64 {
        self.updates[idx(c)]
    }

    /// Raw bit pattern of the per-category EMA state — for bit-identity
    /// assertions (cached/sharded routing must not drift the estimator).
    pub fn c_hat_bits(&self) -> [u64; 4] {
        [
            self.c_hat[0].to_bits(),
            self.c_hat[1].to_bits(),
            self.c_hat[2].to_bits(),
            self.c_hat[3].to_bits(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::tokenizer::count_tokens;

    #[test]
    fn estimate_uses_category_prior() {
        let e = TokenEstimator::default();
        let prose = e.estimate_prompt_tokens(4400, Category::Conversational);
        let code = e.estimate_prompt_tokens(4400, Category::Code);
        assert!(code > prose, "denser categories estimate more tokens");
    }

    #[test]
    fn l_total_adds_output_budget(){
        let e = TokenEstimator::default();
        let t = e.estimate_l_total(4400, 256, Category::Rag);
        assert_eq!(t, e.estimate_prompt_tokens(4400, Category::Rag) + 256);
    }

    #[test]
    fn ema_converges_to_observed_rate() {
        let mut e = TokenEstimator::new(0.2);
        // Feed observations at 6 bytes/token.
        for _ in 0..100 {
            e.update(6000, 1000, Category::Conversational);
        }
        assert!((e.bytes_per_token(Category::Conversational) - 6.0).abs() < 0.05);
        // Other categories untouched.
        assert_eq!(e.bytes_per_token(Category::Code), 3.2);
        assert_eq!(e.update_count(Category::Conversational), 100);
    }

    #[test]
    fn zero_token_updates_ignored() {
        let mut e = TokenEstimator::default();
        let before = e.bytes_per_token(Category::Rag);
        e.update(100, 0, Category::Rag);
        assert_eq!(e.bytes_per_token(Category::Rag), before);
    }

    #[test]
    fn calibrated_estimator_tracks_real_tokenizer() {
        // After updates from the shared tokenizer, estimates should land
        // within ~15% of actual counts on same-distribution text.
        let mut e = TokenEstimator::new(0.1);
        let mut rng = crate::util::rng::Rng::new(42);
        let cfg = crate::compress::corpus::CorpusConfig {
            target_tokens: 800,
            ..Default::default()
        };
        for _ in 0..50 {
            let doc = crate::compress::corpus::generate_document(&cfg, &mut rng);
            e.update(doc.len(), count_tokens(&doc), Category::Rag);
        }
        let doc = crate::compress::corpus::generate_document(&cfg, &mut rng);
        let actual = count_tokens(&doc);
        let est = e.estimate_prompt_tokens(doc.len(), Category::Rag);
        let err = (est as f64 - actual as f64).abs() / actual as f64;
        assert!(err < 0.15, "estimate {est} vs actual {actual} (err {err:.3})");
    }
}
