//! Fingerprint-keyed C&R route memoization (§Perf, PR 8).
//!
//! Production traces are heavily templated: the same prompt shows up
//! thousands of times, and every occurrence pays the full
//! classify → tokenize → score → select gateway cost. [`RouteCache`] is a
//! bounded LRU over *routing outcomes*: a hit replays the stored
//! tier/compressed-text/token-count decision byte-for-byte and skips the
//! entire compression pipeline.
//!
//! Correctness is by construction, not by hoping keys never collide:
//!
//! - **Key** = `(fnv1a(text), max_output_tokens, decision signature)`.
//!   The decision signature ([`GatewayConfig::decision_signature`]) is
//!   the vector of gate regions the request's estimated `L_total` falls
//!   into at every boundary — the *only* way the shared EMA estimator
//!   state can influence a routing outcome. Two requests with the same
//!   text, output budget, and signature take identical gate branches at
//!   every tier, so their outcomes are byte-identical; EMA drift that
//!   does not flip any gate comparison keeps hitting.
//! - **Collisions**: each slot stores the full original text and a probe
//!   verifies it byte-for-byte; a 64-bit hash match with different bytes
//!   counts as [`CacheStats::collisions`] and misses.
//! - **Config fingerprint**: the cache remembers the
//!   [`GatewayConfig::fingerprint`] it was filled under
//!   ([`RouteCache::ensure_config`]); a replan or hot-reload that moves
//!   any boundary/gamma clears every entry (counted as an invalidation).
//! - **Capacity**: `len() <= capacity()` always — an all-unique
//!   adversarial trace evicts in LRU order instead of growing.
//!
//! Slots are generation-counted so the sharded pipeline can *reserve* a
//! slot during its serial decision fold and *fill* it after the parallel
//! compression stage: if the reservation was evicted in between (capacity
//! smaller than a batch's unique set), the stale fill is dropped instead
//! of resurrecting the entry. All probe/reserve operations happen in
//! request order on one thread, so hit/miss stats, eviction victims, and
//! LRU order are identical for every worker count (`tests/
//! gateway_concurrency.rs` pins this against a serial oracle).

use crate::router::gateway::RouteOutcome;
use crate::util::hash::{fnv1a, FxHashMap};

/// Memoization key: text identity (64-bit FNV + byte verification at the
/// slot), the output budget, and the decision signature of the estimate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub text_hash: u64,
    pub max_output_tokens: u32,
    /// Per-boundary gate region of the estimated `L_total`
    /// ([`crate::router::gateway::GatewayConfig::decision_signature`]).
    pub signature: u64,
}

impl CacheKey {
    pub fn new(text: &str, max_output_tokens: u32, signature: u64) -> Self {
        CacheKey {
            text_hash: fnv1a(text.as_bytes()),
            max_output_tokens,
            signature,
        }
    }
}

/// Order-independent cache counters (summed, never averaged, so they
/// merge across batches and report identically for any worker count).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Full-cache clears due to a config fingerprint change.
    pub invalidations: u64,
    /// 64-bit hash matches whose stored text differed byte-wise (counted
    /// as misses; the entry is left in place for its true owner).
    pub collisions: u64,
}

impl CacheStats {
    /// Hit fraction over all lookups (0 when the cache was never probed).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A reserved slot handle: `fill` succeeds only while the slot still
/// holds the same generation (i.e. the reservation was not evicted).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotRef {
    idx: usize,
    gen: u32,
}

/// Probe result. `HitPending` carries the tag passed to
/// [`RouteCache::reserve`] — the sharded pipeline uses the reserving
/// request's index so in-batch duplicates can copy its outcome once the
/// parallel stage computes it.
#[derive(Clone, Debug)]
pub enum Lookup {
    Hit(RouteOutcome),
    HitPending(usize),
    Miss,
}

#[derive(Clone, Debug)]
enum SlotState {
    /// Reserved during a batch's decision fold; filled after compute.
    Pending(usize),
    Filled(RouteOutcome),
}

#[derive(Clone, Debug)]
struct Slot {
    key: CacheKey,
    /// Full original text, for byte-exact collision rejection.
    text: String,
    state: SlotState,
    gen: u32,
    /// Intrusive LRU list links (`NIL` = end).
    prev: usize,
    next: usize,
}

const NIL: usize = usize::MAX;

/// Bounded LRU of routing outcomes. See the module docs for the
/// correctness contract.
#[derive(Debug, Default)]
pub struct RouteCache {
    capacity: usize,
    slots: Vec<Slot>,
    /// Key → slot index. Kept in lockstep with `slots`.
    index: FxHashMap<CacheKey, usize>,
    /// Most- and least-recently-used ends of the intrusive list.
    head: usize,
    tail: usize,
    free: Vec<usize>,
    /// Config fingerprint the current entries were routed under.
    config_fp: Option<u64>,
    pub stats: CacheStats,
}

impl RouteCache {
    /// A cache holding at most `capacity` outcomes (0 = always-miss).
    pub fn new(capacity: usize) -> Self {
        RouteCache {
            capacity,
            slots: Vec::with_capacity(capacity.min(4096)),
            index: FxHashMap::default(),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
            config_fp: None,
            stats: CacheStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Live entries (pending + filled).
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Bind the cache to a config fingerprint, clearing every entry if it
    /// differs from the one the entries were filled under (replan /
    /// hot-reload invalidation). Stats survive; entries do not.
    pub fn ensure_config(&mut self, fingerprint: u64) {
        if self.config_fp == Some(fingerprint) {
            return;
        }
        if self.config_fp.is_some() && !self.index.is_empty() {
            self.stats.invalidations += 1;
        }
        self.clear();
        self.config_fp = Some(fingerprint);
    }

    /// Drop every entry (keeps capacity, stats, and fingerprint binding).
    pub fn clear(&mut self) {
        self.slots.clear();
        self.index.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }

    /// Probe for `key`, verifying `text` byte-for-byte. Counts one hit or
    /// one miss; a filled hit is moved to the front of the LRU list.
    pub fn lookup(&mut self, key: CacheKey, text: &str) -> Lookup {
        let Some(&idx) = self.index.get(&key) else {
            self.stats.misses += 1;
            return Lookup::Miss;
        };
        if self.slots[idx].text != text {
            // Same 64-bit hash, different bytes: never serve it.
            self.stats.collisions += 1;
            self.stats.misses += 1;
            return Lookup::Miss;
        }
        self.detach(idx);
        self.attach_front(idx);
        self.stats.hits += 1;
        match &self.slots[idx].state {
            SlotState::Filled(out) => Lookup::Hit(out.clone()),
            SlotState::Pending(tag) => Lookup::HitPending(*tag),
        }
    }

    /// Reserve a slot for `key` (a pending entry tagged `tag`), evicting
    /// the LRU tail at capacity. Returns `None` when `capacity == 0`. If
    /// the key is already present (collision owner or a re-route after a
    /// stale pending), the slot is re-reserved in place.
    pub fn reserve(&mut self, key: CacheKey, text: &str, tag: usize) -> Option<SlotRef> {
        if self.capacity == 0 {
            return None;
        }
        if let Some(&idx) = self.index.get(&key) {
            let slot = &mut self.slots[idx];
            slot.text.clear();
            slot.text.push_str(text);
            slot.state = SlotState::Pending(tag);
            slot.gen = slot.gen.wrapping_add(1);
            let gen = slot.gen;
            self.detach(idx);
            self.attach_front(idx);
            self.stats.inserts += 1;
            return Some(SlotRef { idx, gen });
        }
        if self.index.len() >= self.capacity {
            self.evict_tail();
        }
        let idx = match self.free.pop() {
            Some(idx) => {
                let slot = &mut self.slots[idx];
                slot.key = key;
                slot.text.clear();
                slot.text.push_str(text);
                slot.state = SlotState::Pending(tag);
                slot.gen = slot.gen.wrapping_add(1);
                idx
            }
            None => {
                self.slots.push(Slot {
                    key,
                    text: text.to_string(),
                    state: SlotState::Pending(tag),
                    gen: 0,
                    prev: NIL,
                    next: NIL,
                });
                self.slots.len() - 1
            }
        };
        self.index.insert(key, idx);
        self.attach_front(idx);
        self.stats.inserts += 1;
        Some(SlotRef {
            idx,
            gen: self.slots[idx].gen,
        })
    }

    /// Fill a reserved slot with its computed outcome. A stale handle
    /// (the reservation was evicted, or the slot re-reserved) is a no-op:
    /// the outcome is simply not cached.
    pub fn fill(&mut self, slot: SlotRef, outcome: RouteOutcome) {
        let Some(s) = self.slots.get_mut(slot.idx) else {
            return;
        };
        if s.gen != slot.gen || !matches!(s.state, SlotState::Pending(_)) {
            return;
        }
        s.state = SlotState::Filled(outcome);
    }

    /// Keys from most- to least-recently used (test/diagnostic surface).
    pub fn keys_lru_order(&self) -> Vec<CacheKey> {
        let mut out = Vec::with_capacity(self.index.len());
        let mut idx = self.head;
        while idx != NIL {
            out.push(self.slots[idx].key);
            idx = self.slots[idx].next;
        }
        out
    }

    fn evict_tail(&mut self) {
        let idx = self.tail;
        if idx == NIL {
            return;
        }
        self.detach(idx);
        let slot = &mut self.slots[idx];
        slot.gen = slot.gen.wrapping_add(1);
        let key = slot.key;
        self.index.remove(&key);
        self.free.push(idx);
        self.stats.evictions += 1;
    }

    fn detach(&mut self, idx: usize) {
        let (prev, next) = (self.slots[idx].prev, self.slots[idx].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else if self.head == idx {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else if self.tail == idx {
            self.tail = prev;
        }
        self.slots[idx].prev = NIL;
        self.slots[idx].next = NIL;
    }

    fn attach_front(&mut self, idx: usize) {
        self.slots[idx].prev = NIL;
        self.slots[idx].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::request::Category;

    fn outcome(tier: usize, text: &str) -> RouteOutcome {
        RouteOutcome {
            tier,
            text: text.to_string(),
            prompt_tokens: text.len() as u32,
            actual_prompt: text.len() as u32,
            category: Category::Conversational,
            compressed: false,
            n_compress_failed: 0,
        }
    }

    fn put(c: &mut RouteCache, text: &str, sig: u64) {
        let key = CacheKey::new(text, 64, sig);
        if let Some(slot) = c.reserve(key, text, 0) {
            c.fill(slot, outcome(0, text));
        }
    }

    #[test]
    fn hit_returns_filled_outcome() {
        let mut c = RouteCache::new(4);
        c.ensure_config(7);
        put(&mut c, "alpha", 1);
        match c.lookup(CacheKey::new("alpha", 64, 1), "alpha") {
            Lookup::Hit(out) => assert_eq!(out.text, "alpha"),
            other => panic!("expected hit, got {other:?}"),
        }
        assert_eq!(c.stats.hits, 1);
    }

    #[test]
    fn capacity_bound_holds_and_evicts_lru() {
        let mut c = RouteCache::new(2);
        c.ensure_config(7);
        put(&mut c, "a", 1);
        put(&mut c, "b", 1);
        // Touch "a" so "b" is the LRU victim.
        assert!(matches!(c.lookup(CacheKey::new("a", 64, 1), "a"), Lookup::Hit(_)));
        put(&mut c, "c", 1);
        assert_eq!(c.len(), 2);
        assert!(matches!(c.lookup(CacheKey::new("b", 64, 1), "b"), Lookup::Miss));
        assert!(matches!(c.lookup(CacheKey::new("a", 64, 1), "a"), Lookup::Hit(_)));
        assert!(matches!(c.lookup(CacheKey::new("c", 64, 1), "c"), Lookup::Hit(_)));
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn fingerprint_change_clears_entries() {
        let mut c = RouteCache::new(4);
        c.ensure_config(7);
        put(&mut c, "a", 1);
        c.ensure_config(7);
        assert_eq!(c.len(), 1);
        c.ensure_config(8);
        assert_eq!(c.len(), 0);
        assert_eq!(c.stats.invalidations, 1);
        assert!(matches!(c.lookup(CacheKey::new("a", 64, 1), "a"), Lookup::Miss));
    }

    #[test]
    fn hash_collision_is_rejected_bytewise() {
        let mut c = RouteCache::new(4);
        c.ensure_config(7);
        put(&mut c, "a", 1);
        // Forge a key with "a"'s hash but different text bytes.
        let forged = CacheKey::new("a", 64, 1);
        assert!(matches!(c.lookup(forged, "z"), Lookup::Miss));
        assert_eq!(c.stats.collisions, 1);
    }

    #[test]
    fn stale_fill_after_eviction_is_dropped() {
        let mut c = RouteCache::new(1);
        c.ensure_config(7);
        let ka = CacheKey::new("a", 64, 1);
        let slot_a = c.reserve(ka, "a", 0).unwrap();
        // "b" evicts pending "a"; the late fill must not resurrect it.
        let kb = CacheKey::new("b", 64, 1);
        let slot_b = c.reserve(kb, "b", 1).unwrap();
        c.fill(slot_a, outcome(0, "a"));
        c.fill(slot_b, outcome(1, "b"));
        assert_eq!(c.len(), 1);
        assert!(matches!(c.lookup(ka, "a"), Lookup::Miss));
        match c.lookup(kb, "b") {
            Lookup::Hit(out) => assert_eq!(out.tier, 1),
            other => panic!("expected hit, got {other:?}"),
        }
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut c = RouteCache::new(0);
        c.ensure_config(7);
        assert!(c.reserve(CacheKey::new("a", 64, 1), "a", 0).is_none());
        assert!(matches!(c.lookup(CacheKey::new("a", 64, 1), "a"), Lookup::Miss));
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn pending_lookup_reports_reserving_tag() {
        let mut c = RouteCache::new(4);
        c.ensure_config(7);
        let k = CacheKey::new("a", 64, 1);
        c.reserve(k, "a", 42).unwrap();
        match c.lookup(k, "a") {
            Lookup::HitPending(tag) => assert_eq!(tag, 42),
            other => panic!("expected pending hit, got {other:?}"),
        }
    }
}
