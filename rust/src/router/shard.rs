//! Sharded gateway admission (§Perf, PR 8): fan a batch across
//! `util::par` workers, each with its own warm [`CompressScratch`],
//! bit-identical to the serial [`Gateway::route`] loop.
//!
//! The serial gateway is not embarrassingly parallel: request k's
//! estimate reads EMA state folded from requests 0..k−1, and the route
//! memo's hit/miss pattern is defined by probe order. The pipeline
//! therefore splits each batch into alternating parallel/serial stages,
//! putting every order-sensitive operation on one thread in request
//! order and every expensive pure computation on the workers:
//!
//! 1. **Features** (parallel): `classify` + `count_tokens` per request —
//!    pure functions of the text.
//! 2. **Decision fold** (serial, request order): estimate from
//!    pre-update EMA state, fold the exact count into the EMA, probe /
//!    reserve the route cache. Exactly the serial path's op order, so
//!    estimator state, cache stats, eviction victims, and LRU order are
//!    identical for every worker count.
//! 3. **Ladder** (parallel): [`route_ladder`] — compression and all — for
//!    the cache misses, strided across workers with one scratch each.
//!    Pure in `(config, text, budget, signature)`, so placement cannot
//!    change a byte.
//! 4. **Emit** (serial, request order): fill reservations, copy in-batch
//!    duplicate outcomes, apply counters, and stream to the sink.
//!
//! The stage split is also why cache-on equals cache-off byte-for-byte:
//! a hit replays a `RouteOutcome` the ladder would have recomputed
//! identically.

use std::time::Instant;

use crate::compress::scratch::CompressScratch;
use crate::compress::tokenizer::count_tokens;
use crate::router::classify::classify;
use crate::router::gateway::{finish_request, route_ladder, Gateway, RouteOutcome, RoutedRequest};
use crate::router::memo::{CacheKey, Lookup, RouteCache, SlotRef};
use crate::util::par;
use crate::workload::request::Category;

/// Per-worker compression scratches, grown on demand and kept warm
/// across batches — steady-state sharded admission allocates no arenas.
#[derive(Clone, Debug, Default)]
pub struct ScratchPool {
    scratches: Vec<CompressScratch>,
}

impl ScratchPool {
    /// At least `n` scratches, as a mutable slice for the fan-out.
    pub fn take(&mut self, n: usize) -> &mut [CompressScratch] {
        if self.scratches.len() < n {
            self.scratches.resize_with(n, CompressScratch::new);
        }
        &mut self.scratches[..n]
    }

    /// Warm scratches currently pooled.
    pub fn len(&self) -> usize {
        self.scratches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scratches.is_empty()
    }
}

/// Wall-clock seconds per pipeline stage for one sharded batch
/// (diagnostics surface for the CLI/example; never compared in tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardTiming {
    /// Effective worker count the batch ran with.
    pub workers: usize,
    pub features_s: f64,
    pub fold_s: f64,
    pub ladder_s: f64,
    pub emit_s: f64,
}

/// The worker count a batch actually runs with: `requested` (0 = auto
/// from available parallelism at ≥ 2 items per worker), clamped by the
/// item count, a hard ceiling of 16, and the process-wide
/// [`par::thread_cap`] (`FLEETOPT_THREADS` / `--threads`).
pub fn effective_workers(requested: usize, items: usize) -> usize {
    let base = if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(items.div_ceil(2))
    } else {
        requested.min(items)
    };
    base.min(16).min(par::thread_cap()).max(1)
}

/// How one request's outcome is produced.
enum Resolution {
    /// Served from the route cache.
    Ready(RouteOutcome),
    /// In-batch duplicate of the request at this index (its reservation
    /// was pending when we probed): copy that outcome after stage 3.
    Dup(usize),
    /// Computed by the parallel ladder stage (index into `pending`).
    Compute(usize),
}

/// Sharded batch routing; `workers` must already be effective (> 1).
/// See the module docs for the stage contract; `gateway.rs` documents
/// the bit-identity guarantee this upholds.
pub(crate) fn route_batch_sharded(
    gw: &mut Gateway,
    batch: &[(&str, u32)],
    workers: usize,
    mut cache: Option<&mut RouteCache>,
    mut sink: impl FnMut(usize, RoutedRequest),
) {
    let n = batch.len();
    let mut timing = ShardTiming {
        workers,
        ..Default::default()
    };

    // Stage 1 — features (parallel, pure).
    let t0 = Instant::now();
    let mut unit = vec![(); workers];
    let pre: Vec<(Category, u32)> =
        par::par_map_with(batch, &mut unit, |_, &(text, _)| {
            (classify(text), count_tokens(text))
        });
    timing.features_s = t0.elapsed().as_secs_f64();

    // Stage 2 — decision fold (serial, request order: EMA + cache ops).
    let t0 = Instant::now();
    if let Some(c) = cache.as_deref_mut() {
        c.ensure_config(gw.cfg.fingerprint());
    }
    let mut est_totals = vec![0u32; n];
    let mut resolution: Vec<Resolution> = Vec::with_capacity(n);
    let mut pending: Vec<(usize, Option<SlotRef>)> = Vec::new();
    for i in 0..n {
        let (text, max_output) = batch[i];
        let (category, actual_prompt) = pre[i];
        let est_total = gw
            .estimator
            .estimate_prompt_tokens(text.len(), category)
            + max_output;
        est_totals[i] = est_total;
        gw.estimator.update(text.len(), actual_prompt, category);
        let res = match cache.as_deref_mut() {
            None => {
                pending.push((i, None));
                Resolution::Compute(pending.len() - 1)
            }
            Some(c) => {
                let key =
                    CacheKey::new(text, max_output, gw.cfg.decision_signature(est_total));
                match c.lookup(key, text) {
                    Lookup::Hit(out) => Resolution::Ready(out),
                    Lookup::HitPending(tag)
                        if matches!(resolution.get(tag), Some(Resolution::Compute(_))) =>
                    {
                        Resolution::Dup(tag)
                    }
                    // Miss — or a pending tag from an earlier batch whose
                    // fill never landed (evicted reservation): recompute.
                    Lookup::HitPending(_) | Lookup::Miss => {
                        let slot = c.reserve(key, text, i);
                        pending.push((i, slot));
                        Resolution::Compute(pending.len() - 1)
                    }
                }
            }
        };
        resolution.push(res);
    }
    timing.fold_s = t0.elapsed().as_secs_f64();

    // Stage 3 — ladder (parallel, pure; one warm scratch per worker).
    let t0 = Instant::now();
    let cfg = &gw.cfg;
    let scratches = gw.shard_pool.take(workers);
    let computed: Vec<(RouteOutcome, f64)> =
        par::par_map_with(&pending, scratches, |scratch, &(i, _)| {
            let (text, max_output) = batch[i];
            let (category, actual_prompt) = pre[i];
            let t = Instant::now();
            let out = route_ladder(
                cfg,
                scratch,
                text,
                max_output,
                category,
                actual_prompt,
                est_totals[i],
            );
            (out, t.elapsed().as_secs_f64())
        });
    timing.ladder_s = t0.elapsed().as_secs_f64();

    // Stage 4 — emit (serial, request order).
    let t0 = Instant::now();
    let mut outcome_by_req: Vec<Option<RouteOutcome>> = vec![None; n];
    for (p, &(i, slot)) in pending.iter().enumerate() {
        if let (Some(c), Some(slot)) = (cache.as_deref_mut(), slot) {
            c.fill(slot, computed[p].0.clone());
        }
        outcome_by_req[i] = Some(computed[p].0.clone());
    }
    for (i, res) in resolution.into_iter().enumerate() {
        let (out, gateway_s) = match res {
            Resolution::Ready(out) => (out, 0.0),
            Resolution::Dup(j) => (
                outcome_by_req[j]
                    .clone()
                    .expect("duplicate of a computed request"),
                0.0,
            ),
            Resolution::Compute(p) => (
                outcome_by_req[i].clone().expect("computed request outcome"),
                computed[p].1,
            ),
        };
        gw.absorb_outcome(&out);
        sink(
            i,
            finish_request(out, batch[i].1, est_totals[i], gateway_s),
        );
    }
    timing.emit_s = t0.elapsed().as_secs_f64();
    gw.last_shard = Some(timing);
}
