//! Stability-guarded admission control in front of the routing ladder
//! (ROADMAP item 4). Projected-KV occupancy per tier drives a watermark
//! pair with hysteresis (the same shape as `router::failover`'s): above
//! the high watermark a tier *engages* and stays engaged until occupancy
//! falls back below the low watermark. An engaged tier escalates through
//! the paper-ordered ladder of graceful degradation — compress harder
//! (tightened gamma within the C&R [1, 2] clamp), defer with a deadline,
//! and only then shed with 429-style accounting — so one long-decode
//! burst cannot destabilize a tier ("Dual-Pool Token-Budget Routing",
//! PAPERS.md). Every decision is counted: `admitted + deferred +
//! recompressed + shed` conserves the offered load.
//!
//! Identity discipline: a disabled controller (`cfg: None`) routes
//! byte-for-byte through [`Gateway::route`] — pinned by
//! `tests/admission_control.rs` with the same verbatim-oracle policy as
//! `tests/gateway_concurrency.rs`.

use crate::compress::gate::band_hi;
use crate::router::classify::classify;
use crate::router::gateway::{Gateway, RoutedRequest};

/// Admission-controller tuning. Occupancies are fractions of a tier's KV
/// capacity in [0, 1]; `high_watermark` engages the controller,
/// `low_watermark` disengages it (hysteresis band between them).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmitConfig {
    /// Engage at or above this projected-KV occupancy.
    pub high_watermark: f64,
    /// Disengage strictly below this occupancy (must be <= high).
    pub low_watermark: f64,
    /// Deadline granted to a deferred request before it is re-decided.
    pub defer_s: f64,
    /// Defers granted per request before shedding (the last resort).
    pub max_defers: u32,
    /// Gamma multiplier for the compress-harder escalation; each
    /// boundary's band is re-clamped into [1, 2] after tightening.
    pub gamma_tighten: f64,
}

impl Default for AdmitConfig {
    fn default() -> Self {
        AdmitConfig {
            high_watermark: 0.85,
            low_watermark: 0.70,
            defer_s: 1.0,
            max_defers: 3,
            gamma_tighten: 1.25,
        }
    }
}

impl AdmitConfig {
    /// Validate, naming the offending field (SkuCatalog error style).
    pub fn validate(&self) -> anyhow::Result<()> {
        let in_unit = |x: f64| x.is_finite() && (0.0..=1.0).contains(&x);
        if !in_unit(self.low_watermark) || !in_unit(self.high_watermark) {
            anyhow::bail!(
                "admit config: watermarks must be inside [0, 1], got low {} high {}",
                self.low_watermark,
                self.high_watermark
            );
        }
        if self.low_watermark > self.high_watermark {
            anyhow::bail!(
                "admit config: low_watermark ({}) must be <= high_watermark ({})",
                self.low_watermark,
                self.high_watermark
            );
        }
        if !self.defer_s.is_finite() || self.defer_s <= 0.0 {
            anyhow::bail!(
                "admit config: defer_s must be positive, got {}",
                self.defer_s
            );
        }
        if !self.gamma_tighten.is_finite() || !(1.0..=2.0).contains(&self.gamma_tighten) {
            anyhow::bail!(
                "admit config: gamma_tighten must be inside [1, 2], got {}",
                self.gamma_tighten
            );
        }
        Ok(())
    }
}

/// Per-tier engagement state with hysteresis. Engagement latches at
/// `occupancy >= high_watermark` and releases at `occupancy <
/// low_watermark`; any constant occupancy therefore settles after one
/// observation and never flaps (pinned in tests).
#[derive(Clone, Debug, Default)]
pub struct AdmitState {
    engaged: Vec<bool>,
}

impl AdmitState {
    /// Fold one occupancy observation for `tier`; returns the (possibly
    /// updated) engagement.
    pub fn observe(&mut self, tier: usize, occupancy: f64, cfg: &AdmitConfig) -> bool {
        if self.engaged.len() <= tier {
            self.engaged.resize(tier + 1, false);
        }
        let next = if self.engaged[tier] {
            occupancy >= cfg.low_watermark
        } else {
            occupancy >= cfg.high_watermark
        };
        self.engaged[tier] = next;
        next
    }

    /// Current engagement of `tier` (false if never observed).
    pub fn engaged(&self, tier: usize) -> bool {
        self.engaged.get(tier).copied().unwrap_or(false)
    }
}

/// What the controller decided for one request attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Routed normally.
    Admit,
    /// Routed through a gamma-tightened ladder (compress harder).
    Recompress,
    /// Not routed; retry after `defer_s`.
    Defer,
    /// Not routed and never will be (429-style rejection).
    Shed,
}

/// The escalation ladder, pure in its inputs: a disengaged tier admits;
/// an engaged tier first compresses harder (when the request is
/// compressible and the tightening is real), then defers up to
/// `max_defers`, and sheds only when both escalations are exhausted.
pub fn decide(
    engaged: bool,
    can_recompress: bool,
    defers_used: u32,
    cfg: &AdmitConfig,
) -> AdmitDecision {
    if !engaged {
        return AdmitDecision::Admit;
    }
    if can_recompress {
        return AdmitDecision::Recompress;
    }
    if defers_used < cfg.max_defers {
        return AdmitDecision::Defer;
    }
    AdmitDecision::Shed
}

/// The compress-harder gammas: each boundary's gamma times `tighten`,
/// capped at the C&R envelope's 2.0 (per-boundary next-tier re-clamping
/// happens where the gammas are consumed, as in `GatewayConfig::tiered`).
pub fn tightened_gammas(gammas: &[f64], tighten: f64) -> Vec<f64> {
    gammas.iter().map(|g| (g * tighten).min(2.0)).collect()
}

/// Decision counters; `total()` conserves the offered load.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmitCounters {
    pub admitted: u64,
    pub deferred: u64,
    pub recompressed: u64,
    pub shed: u64,
}

impl AdmitCounters {
    /// Terminal decisions plus outstanding defers — equals the number of
    /// attempts when every deferred request is eventually re-decided.
    pub fn total(&self) -> u64 {
        self.admitted + self.deferred + self.recompressed + self.shed
    }
}

/// The tier the ladder would choose for this request *if admitted*,
/// computed read-only from the estimator (no EMA update, no counters):
/// the first tier whose boundary fits the estimate, or whose band could
/// absorb a compressible request. This is the occupancy the admission
/// decision is held against.
pub fn predict_tier(gw: &Gateway, text: &str, max_output_tokens: u32) -> usize {
    let category = classify(text);
    let est_total = gw
        .estimator
        .estimate_prompt_tokens(text.len(), category)
        + max_output_tokens;
    for (i, tr) in gw.cfg.tiers.iter().enumerate() {
        if est_total <= tr.boundary {
            return i;
        }
        let gamma = if gw.cfg.enable_cr { tr.gamma } else { 1.0 };
        if category.compressible() && est_total <= band_hi(tr.boundary, gamma) {
            return i;
        }
    }
    gw.cfg.tiers.len()
}

/// The stateful admission controller wrapping one [`Gateway`]. `cfg:
/// None` disables it: every request takes [`Gateway::route`] verbatim
/// (bit-identical routing, estimator, and counters — the oracle-pinned
/// contract).
#[derive(Debug, Default)]
pub struct AdmissionController {
    pub cfg: Option<AdmitConfig>,
    pub state: AdmitState,
    pub counters: AdmitCounters,
}

impl AdmissionController {
    pub fn new(cfg: Option<AdmitConfig>) -> Self {
        AdmissionController {
            cfg,
            state: AdmitState::default(),
            counters: AdmitCounters::default(),
        }
    }

    /// Decide-and-route one request attempt. `occupancy[tier]` is the
    /// projected KV occupancy per tier (missing tiers read 0.0);
    /// `defers_used` is how many times this request was already
    /// deferred. Deferred and shed requests return no route; the caller
    /// re-submits a deferred request after `defer_s`.
    pub fn route(
        &mut self,
        gw: &mut Gateway,
        text: &str,
        max_output_tokens: u32,
        occupancy: &[f64],
        defers_used: u32,
    ) -> (AdmitDecision, Option<RoutedRequest>) {
        let Some(cfg) = self.cfg else {
            self.counters.admitted += 1;
            return (AdmitDecision::Admit, Some(gw.route(text, max_output_tokens)));
        };
        let tier = predict_tier(gw, text, max_output_tokens);
        let occ = occupancy.get(tier).copied().unwrap_or(0.0);
        let engaged = self.state.observe(tier, occ, &cfg);
        // Compress-harder is a terminal escalation: it admits (into a
        // tightened band), so it is attempted at most once per request.
        let can_recompress = defers_used == 0
            && cfg.gamma_tighten > 1.0
            && gw.cfg.enable_cr
            && classify(text).compressible();
        match decide(engaged, can_recompress, defers_used, &cfg) {
            AdmitDecision::Admit => {
                self.counters.admitted += 1;
                (AdmitDecision::Admit, Some(gw.route(text, max_output_tokens)))
            }
            AdmitDecision::Recompress => {
                self.counters.recompressed += 1;
                (
                    AdmitDecision::Recompress,
                    Some(gw.route_tightened(text, max_output_tokens, cfg.gamma_tighten)),
                )
            }
            AdmitDecision::Defer => {
                self.counters.deferred += 1;
                (AdmitDecision::Defer, None)
            }
            AdmitDecision::Shed => {
                self.counters.shed += 1;
                (AdmitDecision::Shed, None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        AdmitConfig::default().validate().unwrap();
    }

    #[test]
    fn validation_names_the_offending_field() {
        let base = AdmitConfig::default();
        let cases: [(AdmitConfig, &str); 4] = [
            (
                AdmitConfig {
                    high_watermark: 1.5,
                    ..base
                },
                "watermarks",
            ),
            (
                AdmitConfig {
                    low_watermark: 0.9,
                    high_watermark: 0.8,
                    ..base
                },
                "low_watermark",
            ),
            (
                AdmitConfig {
                    defer_s: 0.0,
                    ..base
                },
                "defer_s",
            ),
            (
                AdmitConfig {
                    gamma_tighten: 2.5,
                    ..base
                },
                "gamma_tighten",
            ),
        ];
        for (bad, field) in cases {
            let err = bad.validate().unwrap_err().to_string();
            assert!(err.contains(field), "{err}");
        }
    }

    #[test]
    fn observe_hysteresis_never_flaps_on_constant_occupancy() {
        let cfg = AdmitConfig::default();
        // For ANY constant occupancy, state settles after one observation
        // and stays put forever — including inside the hysteresis band.
        for occ100 in 0..=100 {
            let occ = occ100 as f64 / 100.0;
            let mut st = AdmitState::default();
            let first = st.observe(0, occ, &cfg);
            for _ in 0..50 {
                assert_eq!(st.observe(0, occ, &cfg), first, "occ {occ}");
            }
        }
    }

    #[test]
    fn observe_engages_high_releases_low() {
        let cfg = AdmitConfig::default();
        let mut st = AdmitState::default();
        assert!(!st.observe(0, 0.84, &cfg), "below high: stays out");
        assert!(st.observe(0, 0.85, &cfg), "at high: engages");
        assert!(st.observe(0, 0.75, &cfg), "inside band: stays engaged");
        assert!(st.observe(0, 0.70, &cfg), "at low: still engaged");
        assert!(!st.observe(0, 0.69, &cfg), "below low: releases");
        assert!(!st.observe(0, 0.80, &cfg), "band from below: stays out");
        // Tiers are independent.
        assert!(st.observe(2, 0.9, &cfg));
        assert!(!st.engaged(0));
        assert!(st.engaged(2));
        assert!(!st.engaged(7), "unobserved tier reads disengaged");
    }

    #[test]
    fn decision_ladder_ordering() {
        let cfg = AdmitConfig::default(); // max_defers = 3
        assert_eq!(decide(false, true, 0, &cfg), AdmitDecision::Admit);
        assert_eq!(decide(false, false, 99, &cfg), AdmitDecision::Admit);
        // Engaged: recompress first when available...
        assert_eq!(decide(true, true, 0, &cfg), AdmitDecision::Recompress);
        // ...then defer until the budget is exhausted...
        for d in 0..3 {
            assert_eq!(decide(true, false, d, &cfg), AdmitDecision::Defer);
        }
        // ...and shed only as the last resort.
        assert_eq!(decide(true, false, 3, &cfg), AdmitDecision::Shed);
        let no_defers = AdmitConfig {
            max_defers: 0,
            ..cfg
        };
        assert_eq!(decide(true, false, 0, &no_defers), AdmitDecision::Shed);
    }

    #[test]
    fn tightened_gammas_respect_the_clamp() {
        let g = tightened_gammas(&[1.5, 1.9, 1.0], 1.25);
        assert!((g[0] - 1.875).abs() < 1e-12);
        assert!((g[1] - 2.0).abs() < 1e-12, "capped at 2");
        assert!((g[2] - 1.25).abs() < 1e-12);
        // tighten = 1 is the identity.
        assert_eq!(tightened_gammas(&[1.5, 1.2], 1.0), vec![1.5, 1.2]);
    }

    #[test]
    fn counters_total_sums_all_decisions() {
        let c = AdmitCounters {
            admitted: 5,
            deferred: 3,
            recompressed: 2,
            shed: 1,
        };
        assert_eq!(c.total(), 11);
    }
}
