//! The gateway router (paper §2.1, §5.1): per-category token-budget
//! estimation (EMA), content classification, and pool routing with
//! Compress-and-Route inline on the request path.

pub mod classify;
pub mod estimator;
pub mod gateway;

pub use classify::classify;
pub use estimator::TokenEstimator;
pub use gateway::{Gateway, GatewayConfig, RoutedRequest, TierRoute};
