//! The gateway router (paper §2.1, §5.1): per-category token-budget
//! estimation (EMA), content classification, and pool routing with
//! Compress-and-Route inline on the request path — plus the sharded
//! admission pipeline (`shard`) and the fingerprint-keyed route memo
//! (`memo`) layered on top (§Perf, PR 8), and degraded-capacity failover
//! (`failover`): hysteretic tier-drop + gamma-boost spill for chaos runs,
//! and KV-pressure admission control (`admit`): watermark-hysteresis
//! admit / compress-harder / defer / shed in front of the ladder.

pub mod admit;
pub mod classify;
pub mod estimator;
pub mod failover;
pub mod gateway;
pub mod memo;
pub mod shard;

pub use admit::{
    decide, tightened_gammas, AdmissionController, AdmitConfig, AdmitCounters,
    AdmitDecision, AdmitState,
};
pub use classify::classify;
pub use estimator::TokenEstimator;
pub use failover::{
    effective_gateway_config, effective_routes, FailoverConfig, FailoverState,
};
pub use gateway::{Gateway, GatewayConfig, GatewayMetrics, RoutedRequest, TierRoute};
pub use memo::{CacheKey, CacheStats, Lookup, RouteCache};
pub use shard::{effective_workers, ScratchPool, ShardTiming};
