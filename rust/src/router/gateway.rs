//! The gateway: classify → estimate → route, with C&R inline (paper §2.1,
//! §5.1). This is the request-path embodiment of the planner's boundary:
//! requests at or below `B_short` go short; borderline compressible
//! requests are extractively compressed to `T_c = B_short − L_out` and
//! re-routed short (the "virtual pool"); everything else goes long.

use crate::compress::extractive::compress_with;
use crate::compress::gate::{compression_budget, gate, GateDecision};
use crate::compress::scratch::CompressScratch;
use crate::compress::tokenizer::count_tokens;
use crate::router::classify::classify;
use crate::router::estimator::TokenEstimator;
use crate::runtime::PoolKind;
use crate::workload::request::Category;

/// Gateway configuration: the planner's output (B_short, gamma) applied at
/// the request path.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    pub b_short: u32,
    pub gamma: f64,
    /// Compression enabled (false = plain pool routing baseline).
    pub enable_cr: bool,
}

/// A routed request, ready for an engine pool.
#[derive(Clone, Debug)]
pub struct RoutedRequest {
    pub pool: PoolKind,
    /// Final prompt text (compressed when C&R fired).
    pub text: String,
    /// Actual prompt tokens of `text` (shared tokenizer).
    pub prompt_tokens: u32,
    pub max_output_tokens: u32,
    pub category: Category,
    /// Estimated L_total used for the routing decision.
    pub estimated_l_total: u32,
    pub compressed: bool,
    /// Gateway processing time for this request, seconds.
    pub gateway_s: f64,
}

/// The stateful gateway (one per deployment; EMA state is shared across
/// requests exactly as in §2.1).
///
/// §Perf: the gateway owns a [`CompressScratch`] so every C&R compression
/// reuses the same parse/score/select buffers — steady-state routing
/// performs no heap allocation beyond the returned `RoutedRequest`.
#[derive(Debug)]
pub struct Gateway {
    pub cfg: GatewayConfig,
    pub estimator: TokenEstimator,
    scratch: CompressScratch,
    pub n_routed_short: u64,
    pub n_routed_long: u64,
    pub n_compressed: u64,
    pub n_compress_failed: u64,
}

impl Gateway {
    pub fn new(cfg: GatewayConfig) -> Self {
        Gateway {
            cfg,
            estimator: TokenEstimator::default(),
            scratch: CompressScratch::new(),
            n_routed_short: 0,
            n_routed_long: 0,
            n_compressed: 0,
            n_compress_failed: 0,
        }
    }

    /// Route one request. The returned `text` is what the engine prefills.
    pub fn route(&mut self, text: &str, max_output_tokens: u32) -> RoutedRequest {
        let t0 = std::time::Instant::now();
        let category = classify(text);
        let est_prompt = self
            .estimator
            .estimate_prompt_tokens(text.len(), category);
        let est_total = est_prompt + max_output_tokens;

        // Post-hoc EMA update from the true count (the engine tokenizes
        // anyway; the estimate must be cheap, the update can be exact).
        let actual_prompt = count_tokens(text);
        self.estimator.update(text.len(), actual_prompt, category);

        let gamma = if self.cfg.enable_cr { self.cfg.gamma } else { 1.0 };
        let decision = gate(est_total, self.cfg.b_short, gamma, category);

        let routed = match decision {
            GateDecision::RouteShort => RoutedRequest {
                pool: PoolKind::Short,
                text: text.to_string(),
                prompt_tokens: actual_prompt,
                max_output_tokens,
                category,
                estimated_l_total: est_total,
                compressed: false,
                gateway_s: 0.0,
            },
            GateDecision::CompressAndRoute => {
                match compression_budget(self.cfg.b_short, max_output_tokens) {
                    Some(budget) => {
                        let c = compress_with(&mut self.scratch, text, budget);
                        if c.ok {
                            self.n_compressed += 1;
                            RoutedRequest {
                                pool: PoolKind::Short,
                                prompt_tokens: count_tokens(&c.text),
                                text: c.text,
                                max_output_tokens,
                                category,
                                estimated_l_total: est_total,
                                compressed: true,
                                gateway_s: 0.0,
                            }
                        } else {
                            self.n_compress_failed += 1;
                            self.long(text, actual_prompt, max_output_tokens, category, est_total)
                        }
                    }
                    None => {
                        self.n_compress_failed += 1;
                        self.long(text, actual_prompt, max_output_tokens, category, est_total)
                    }
                }
            }
            GateDecision::BandButUnsafe | GateDecision::RouteLong => {
                self.long(text, actual_prompt, max_output_tokens, category, est_total)
            }
        };
        match routed.pool {
            PoolKind::Short => self.n_routed_short += 1,
            PoolKind::Long => self.n_routed_long += 1,
        }
        RoutedRequest {
            gateway_s: t0.elapsed().as_secs_f64(),
            ..routed
        }
    }

    fn long(
        &self,
        text: &str,
        prompt_tokens: u32,
        max_output_tokens: u32,
        category: Category,
        est: u32,
    ) -> RoutedRequest {
        RoutedRequest {
            pool: PoolKind::Long,
            text: text.to_string(),
            prompt_tokens,
            max_output_tokens,
            category,
            estimated_l_total: est,
            compressed: false,
            gateway_s: 0.0,
        }
    }

    /// Route a batch of `(text, max_output_tokens)` requests, streaming
    /// each result to `sink` **as soon as it is routed** — so a dispatcher
    /// can enqueue request k while request k+1 is still being compressed
    /// (no head-of-line blocking on the batch). Routing semantics are
    /// identical to calling [`Gateway::route`] per item in order; the
    /// batch form keeps one warm pass over the shared scratch per due
    /// window (§Perf) and is what `coordinator::serve` uses.
    pub fn route_batch_with(
        &mut self,
        batch: &[(&str, u32)],
        mut sink: impl FnMut(usize, RoutedRequest),
    ) {
        for (k, &(text, max_output)) in batch.iter().enumerate() {
            sink(k, self.route(text, max_output));
        }
    }

    /// Collecting wrapper over [`Gateway::route_batch_with`].
    pub fn route_batch(&mut self, batch: &[(&str, u32)]) -> Vec<RoutedRequest> {
        let mut out = Vec::with_capacity(batch.len());
        self.route_batch_with(batch, |_, routed| out.push(routed));
        out
    }

    /// Realized alpha' (Eq. 14 diagnostics).
    pub fn alpha_prime(&self) -> f64 {
        let total = self.n_routed_short + self.n_routed_long;
        if total == 0 {
            0.0
        } else {
            self.n_routed_short as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::corpus::{self, CorpusConfig};
    use crate::util::rng::Rng;

    fn gw(b_short: u32, enable_cr: bool) -> Gateway {
        Gateway::new(GatewayConfig {
            b_short,
            gamma: 1.5,
            enable_cr,
        })
    }

    fn doc(tokens: u32, rng: &mut Rng) -> String {
        corpus::generate_document(
            &CorpusConfig {
                target_tokens: tokens,
                ..Default::default()
            },
            rng,
        )
    }

    #[test]
    fn short_requests_route_short_untouched() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(1);
        let text = doc(500, &mut rng);
        let r = g.route(&text, 64);
        assert_eq!(r.pool, PoolKind::Short);
        assert!(!r.compressed);
        assert_eq!(r.text, text);
    }

    #[test]
    fn borderline_prose_is_compressed_short() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(2);
        // ~2600 tokens: inside (2048, 3072].
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 128);
        assert_eq!(r.pool, PoolKind::Short, "decision for {} est tokens", r.estimated_l_total);
        assert!(r.compressed);
        // Hard OOM guarantee at the gateway: prompt + output <= B.
        assert!(
            r.prompt_tokens + r.max_output_tokens <= 2048,
            "{} + {} > 2048",
            r.prompt_tokens,
            r.max_output_tokens
        );
        assert_eq!(g.n_compressed, 1);
    }

    #[test]
    fn borderline_code_goes_long() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(3);
        let code = corpus::generate_code(2600, &mut rng);
        let r = g.route(&code, 128);
        assert_eq!(r.pool, PoolKind::Long);
        assert!(!r.compressed);
        assert_eq!(g.n_compressed, 0);
    }

    #[test]
    fn cr_disabled_sends_borderline_long() {
        let mut g = gw(2048, false);
        let mut rng = Rng::new(4);
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 128);
        assert_eq!(r.pool, PoolKind::Long);
    }

    #[test]
    fn genuinely_long_routes_long() {
        let mut g = gw(1024, true);
        let mut rng = Rng::new(5);
        let text = doc(4000, &mut rng); // far above gamma * B
        let r = g.route(&text, 128);
        assert_eq!(r.pool, PoolKind::Long);
    }

    #[test]
    fn output_budget_exceeding_boundary_fails_safe() {
        let mut g = gw(1024, true);
        let mut rng = Rng::new(6);
        // Small prompt, huge output budget: estimated L_total lands in the
        // band but L_out >= B, so no compression can make it fit.
        let text = doc(300, &mut rng);
        let r = g.route(&text, 1100);
        assert!(r.estimated_l_total > 1024 && r.estimated_l_total <= 1536);
        assert_eq!(r.pool, PoolKind::Long);
        assert_eq!(g.n_compress_failed, 1);
    }

    #[test]
    fn stats_track_routing() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let t = doc(400, &mut rng);
            g.route(&t, 32);
        }
        let long_text = doc(8000, &mut rng);
        g.route(&long_text, 32);
        assert_eq!(g.n_routed_short, 5);
        assert_eq!(g.n_routed_long, 1);
        assert!((g.alpha_prime() - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn route_batch_matches_sequential_route() {
        let mut rng = Rng::new(9);
        let texts: Vec<String> = (0..6)
            .map(|i| doc(if i % 2 == 0 { 400 } else { 2600 }, &mut rng))
            .collect();
        let batch: Vec<(&str, u32)> = texts.iter().map(|t| (t.as_str(), 64)).collect();
        let mut g1 = gw(2048, true);
        let routed = g1.route_batch(&batch);
        let mut g2 = gw(2048, true);
        for (item, r1) in batch.iter().zip(&routed) {
            let r2 = g2.route(item.0, item.1);
            assert_eq!(r1.pool, r2.pool);
            assert_eq!(r1.text, r2.text);
            assert_eq!(r1.compressed, r2.compressed);
            assert_eq!(r1.prompt_tokens, r2.prompt_tokens);
        }
        assert_eq!(g1.n_compressed, g2.n_compressed);
        assert_eq!(g1.n_routed_short, g2.n_routed_short);
    }

    #[test]
    fn gateway_latency_is_recorded() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(8);
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 64);
        assert!(r.gateway_s > 0.0 && r.gateway_s < 1.0);
    }
}
