//! The gateway: classify → estimate → route, with C&R inline (paper §2.1,
//! §5.1), generalized to K-tier fleets. This is the request-path
//! embodiment of the planner's boundaries: a request takes the first tier
//! whose boundary fits it; a borderline compressible request just above
//! tier i's boundary is extractively compressed to `T_c = B_i − L_out`
//! and routed *into tier i* (the "virtual pool", per boundary); everything
//! else falls through to the last (full-context) tier. With a single
//! boundary this is the paper's two-pool gateway, decision for decision.
//!
//! §Perf (PR 8): routing decomposes into a *pure ladder*
//! ([`route_ladder`] — a function of config, text, output budget, and the
//! estimate's decision signature only) plus a cheap serial fold (EMA
//! estimate/update, counters). That split is what makes the sharded
//! pipeline (`router::shard`) and the route memo (`router::memo`)
//! bit-identical to this serial path by construction.

use crate::compress::extractive::compress_with;
use crate::compress::gate::{band_hi, clamp_gamma, compression_budget, gate, GateDecision};
use crate::compress::scratch::CompressScratch;
use crate::compress::tokenizer::count_tokens;
use crate::router::classify::classify;
use crate::router::estimator::TokenEstimator;
use crate::router::memo::{CacheKey, Lookup, RouteCache};
use crate::router::shard::{self, ScratchPool, ShardTiming};
use crate::util::hash::{fnv1a_words, FNV_OFFSET};
use crate::workload::request::Category;

/// One routing boundary: requests at or below `boundary` fit this tier;
/// the C&R band reaches up to `gamma * boundary`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierRoute {
    pub boundary: u32,
    pub gamma: f64,
}

/// Gateway configuration: the planner's output boundaries applied at the
/// request path. `tiers` holds the K−1 boundaries in ascending order; the
/// implicit last tier takes everything above them.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    pub tiers: Vec<TierRoute>,
    /// Compression enabled (false = plain pool routing baseline).
    pub enable_cr: bool,
}

impl GatewayConfig {
    /// The paper's two-pool configuration: one boundary, one band.
    pub fn two_tier(b_short: u32, gamma: f64, enable_cr: bool) -> Self {
        GatewayConfig {
            tiers: vec![TierRoute {
                boundary: b_short,
                gamma,
            }],
            enable_cr,
        }
    }

    /// K-tier configuration with one shared gamma at every boundary. Each
    /// boundary's band is clamped at the next boundary up
    /// ([`clamp_gamma`]): traffic in `(B_{i+1}, gamma B_i]` belongs to a
    /// tier the planner's adjacent-transfer accounting never moves, so
    /// the router must not claim it either.
    pub fn tiered(boundaries: &[u32], gamma: f64, enable_cr: bool) -> Self {
        assert!(!boundaries.is_empty());
        GatewayConfig {
            tiers: boundaries
                .iter()
                .enumerate()
                .map(|(i, &boundary)| TierRoute {
                    boundary,
                    gamma: clamp_gamma(boundary, boundaries.get(i + 1).copied(), gamma),
                })
                .collect(),
            enable_cr,
        }
    }

    /// Number of tiers K (boundaries + the implicit last tier).
    pub fn n_tiers(&self) -> usize {
        self.tiers.len() + 1
    }

    /// The first boundary (the paper's `B_short` at K = 2).
    pub fn b_short(&self) -> u32 {
        self.tiers[0].boundary
    }

    /// FNV-1a fingerprint of every config input a routing decision reads:
    /// per-tier `(boundary, gamma bits)` and the C&R switch. The route
    /// memo binds its entries to this value, so a replanned or
    /// hot-reloaded boundary/gamma mints a fresh fingerprint and
    /// invalidates every cached decision ([`RouteCache::ensure_config`]).
    pub fn fingerprint(&self) -> u64 {
        let mut h = fnv1a_words(
            FNV_OFFSET,
            &[self.tiers.len() as u64, self.enable_cr as u64],
        );
        for tr in &self.tiers {
            h = fnv1a_words(h, &[tr.boundary as u64, tr.gamma.to_bits()]);
        }
        h
    }

    /// The effective (re-clamped) gamma the ladder uses at `tier`.
    fn effective_gamma(&self, tier: usize) -> f64 {
        let tr = self.tiers[tier];
        let gamma = if self.enable_cr { tr.gamma } else { 1.0 };
        // Re-clamp at use: `tiers` is public, so a hand-built config may
        // carry unclamped gammas (no-op otherwise).
        clamp_gamma(
            tr.boundary,
            self.tiers.get(tier + 1).map(|t| t.boundary),
            gamma,
        )
    }

    /// The decision signature of an estimated `L_total` under this
    /// config: at every boundary, which of the three gate regions the
    /// estimate falls in (at-or-below / inside the C&R band / above),
    /// folded base-3 in tier order. Routing outcomes are a pure function
    /// of `(text, max_output_tokens, signature)` — the signature captures
    /// every comparison [`gate`] can make against the estimate — so the
    /// route memo keys on it instead of the raw estimate: shared-EMA
    /// drift that does not flip any gate comparison still hits.
    pub fn decision_signature(&self, est_total: u32) -> u64 {
        let mut sig = 0u64;
        for tier in 0..self.tiers.len() {
            let boundary = self.tiers[tier].boundary;
            let region = if est_total <= boundary {
                0u64
            } else if est_total <= band_hi(boundary, self.effective_gamma(tier)) {
                1
            } else {
                2
            };
            sig = sig.wrapping_mul(3).wrapping_add(region);
        }
        sig
    }
}

/// A routed request, ready for an engine pool.
#[derive(Clone, Debug)]
pub struct RoutedRequest {
    /// Destination tier index (0 = densest pool, K−1 = full-context pool).
    pub tier: usize,
    /// Final prompt text (compressed when C&R fired).
    pub text: String,
    /// Actual prompt tokens of `text` (shared tokenizer).
    pub prompt_tokens: u32,
    pub max_output_tokens: u32,
    pub category: Category,
    /// Estimated L_total used for the routing decision.
    pub estimated_l_total: u32,
    pub compressed: bool,
    /// Gateway processing time for this request, seconds.
    pub gateway_s: f64,
}

/// The memoizable part of a routing decision: everything [`route_ladder`]
/// produces. Pure in `(config, text, max_output_tokens, decision
/// signature)`, so it is what [`RouteCache`] stores and what the sharded
/// pipeline computes in parallel.
#[derive(Clone, Debug, PartialEq)]
pub struct RouteOutcome {
    pub tier: usize,
    /// Final prompt text (compressed when C&R fired).
    pub text: String,
    pub prompt_tokens: u32,
    /// Uncompressed token count of the *original* text — replayed into
    /// the EMA estimator on cache hits so estimator state stays
    /// bit-identical to cold routing.
    pub actual_prompt: u32,
    pub category: Category,
    pub compressed: bool,
    /// Band tiers where compression was attempted and failed (or had no
    /// feasible budget) before this outcome was reached.
    pub n_compress_failed: u32,
}

/// The tier ladder (the decision core of [`Gateway::route`]): walk the
/// boundaries in order, compressing into the first band that accepts.
/// Pure in its arguments — no estimator, no counters — which is the
/// property the memo and the shard pipeline rely on.
pub(crate) fn route_ladder(
    cfg: &GatewayConfig,
    scratch: &mut CompressScratch,
    text: &str,
    max_output_tokens: u32,
    category: Category,
    actual_prompt: u32,
    est_total: u32,
) -> RouteOutcome {
    let last_tier = cfg.tiers.len();
    let mut n_compress_failed = 0u32;
    for tier in 0..last_tier {
        let boundary = cfg.tiers[tier].boundary;
        match gate(est_total, boundary, cfg.effective_gamma(tier), category) {
            GateDecision::RouteShort => {
                return RouteOutcome {
                    tier,
                    text: text.to_string(),
                    prompt_tokens: actual_prompt,
                    actual_prompt,
                    category,
                    compressed: false,
                    n_compress_failed,
                };
            }
            GateDecision::CompressAndRoute => {
                match compression_budget(boundary, max_output_tokens) {
                    Some(budget) => {
                        let c = compress_with(scratch, text, budget);
                        if c.ok {
                            return RouteOutcome {
                                tier,
                                prompt_tokens: count_tokens(&c.text),
                                text: c.text,
                                actual_prompt,
                                category,
                                compressed: true,
                                n_compress_failed,
                            };
                        }
                        // Compression failed: fall through to the next
                        // tier up (at K = 2, the long pool).
                        n_compress_failed += 1;
                    }
                    None => {
                        n_compress_failed += 1;
                    }
                }
            }
            GateDecision::BandButUnsafe | GateDecision::RouteLong => {}
        }
    }
    RouteOutcome {
        tier: last_tier,
        text: text.to_string(),
        prompt_tokens: actual_prompt,
        actual_prompt,
        category,
        compressed: false,
        n_compress_failed,
    }
}

/// Assemble the engine-facing request from a ladder outcome.
pub(crate) fn finish_request(
    out: RouteOutcome,
    max_output_tokens: u32,
    est_total: u32,
    gateway_s: f64,
) -> RoutedRequest {
    RoutedRequest {
        tier: out.tier,
        text: out.text,
        prompt_tokens: out.prompt_tokens,
        max_output_tokens,
        category: out.category,
        estimated_l_total: est_total,
        compressed: out.compressed,
        gateway_s,
    }
}

/// Gateway routing counters, decoupled from the [`Gateway`] so they can
/// be compared, merged (order-independent sums), and reported uniformly
/// by the serial path, the sharded pipeline, and the benches.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GatewayMetrics {
    /// Requests routed to each tier (len K).
    pub n_routed: Vec<u64>,
    pub n_compressed: u64,
    pub n_compress_failed: u64,
}

impl GatewayMetrics {
    /// Elementwise counter sum (tier vectors are length-matched by
    /// zero-extension). Summation commutes, so any merge order over any
    /// sharding of the same requests yields identical totals.
    pub fn merge(&mut self, other: &GatewayMetrics) {
        if other.n_routed.len() > self.n_routed.len() {
            self.n_routed.resize(other.n_routed.len(), 0);
        }
        for (a, b) in self.n_routed.iter_mut().zip(&other.n_routed) {
            *a += b;
        }
        self.n_compressed += other.n_compressed;
        self.n_compress_failed += other.n_compress_failed;
    }

    /// Total requests routed.
    pub fn n_total(&self) -> u64 {
        self.n_routed.iter().sum()
    }
}

/// The stateful gateway (one per deployment; EMA state is shared across
/// requests exactly as in §2.1).
///
/// §Perf: the gateway owns a [`CompressScratch`] so every C&R compression
/// reuses the same parse/score/select buffers — steady-state routing
/// performs no heap allocation beyond the returned `RoutedRequest`. The
/// sharded batch path keeps one warm scratch per worker in `shard_pool`.
#[derive(Debug)]
pub struct Gateway {
    pub cfg: GatewayConfig,
    pub estimator: TokenEstimator,
    scratch: CompressScratch,
    /// Per-worker scratch arenas for the sharded batch path, kept warm
    /// across batches.
    pub(crate) shard_pool: ScratchPool,
    /// Stage timings of the most recent sharded batch (None until the
    /// sharded path has run).
    pub last_shard: Option<ShardTiming>,
    /// Requests routed to each tier (len K).
    pub n_routed: Vec<u64>,
    pub n_compressed: u64,
    pub n_compress_failed: u64,
    /// Failover re-route decisions ([`Gateway::reroute_failed`]) — kept
    /// out of `n_routed`/[`GatewayMetrics`] so a retry storm leaves the
    /// first-attempt accounting (and the EMA estimator) untouched.
    pub n_rerouted: u64,
}

impl Gateway {
    pub fn new(cfg: GatewayConfig) -> Self {
        let k = cfg.n_tiers();
        Gateway {
            cfg,
            estimator: TokenEstimator::default(),
            scratch: CompressScratch::new(),
            shard_pool: ScratchPool::default(),
            last_shard: None,
            n_routed: vec![0; k],
            n_compressed: 0,
            n_compress_failed: 0,
            n_rerouted: 0,
        }
    }

    /// Requests routed to the densest tier.
    pub fn n_routed_short(&self) -> u64 {
        self.n_routed[0]
    }

    /// Requests routed to the full-context (last) tier.
    pub fn n_routed_long(&self) -> u64 {
        *self.n_routed.last().expect("at least two tiers")
    }

    /// Snapshot of the routing counters.
    pub fn metrics(&self) -> GatewayMetrics {
        GatewayMetrics {
            n_routed: self.n_routed.clone(),
            n_compressed: self.n_compressed,
            n_compress_failed: self.n_compress_failed,
        }
    }

    /// Apply a ladder outcome to the counters (one request routed).
    pub(crate) fn absorb_outcome(&mut self, out: &RouteOutcome) {
        self.n_routed[out.tier] += 1;
        if out.compressed {
            self.n_compressed += 1;
        }
        self.n_compress_failed += u64::from(out.n_compress_failed);
    }

    /// Route one request. The returned `text` is what the engine prefills.
    pub fn route(&mut self, text: &str, max_output_tokens: u32) -> RoutedRequest {
        let t0 = std::time::Instant::now();
        let category = classify(text);
        let est_prompt = self
            .estimator
            .estimate_prompt_tokens(text.len(), category);
        let est_total = est_prompt + max_output_tokens;

        // Post-hoc EMA update from the true count (the engine tokenizes
        // anyway; the estimate must be cheap, the update can be exact).
        let actual_prompt = count_tokens(text);
        self.estimator.update(text.len(), actual_prompt, category);

        let out = route_ladder(
            &self.cfg,
            &mut self.scratch,
            text,
            max_output_tokens,
            category,
            actual_prompt,
            est_total,
        );
        self.absorb_outcome(&out);
        finish_request(out, max_output_tokens, est_total, t0.elapsed().as_secs_f64())
    }

    /// Route one request through a gamma-tightened view of the config —
    /// the admission controller's compress-harder escalation
    /// (`router::admit`). Every boundary's gamma is multiplied by
    /// `tighten` and re-clamped into the C&R band's [1, 2] envelope and
    /// at the next boundary up (exactly like [`GatewayConfig::tiered`]),
    /// so a pressured tier pulls more borderline traffic down the ladder
    /// without ever widening a band past what the planner's
    /// adjacent-transfer accounting allows. Estimator update and
    /// counters behave exactly like [`Gateway::route`]; `tighten = 1`
    /// routes bit-identically to it.
    pub fn route_tightened(
        &mut self,
        text: &str,
        max_output_tokens: u32,
        tighten: f64,
    ) -> RoutedRequest {
        let t0 = std::time::Instant::now();
        let category = classify(text);
        let est_prompt = self
            .estimator
            .estimate_prompt_tokens(text.len(), category);
        let est_total = est_prompt + max_output_tokens;
        let actual_prompt = count_tokens(text);
        self.estimator.update(text.len(), actual_prompt, category);
        let tight = GatewayConfig {
            tiers: self
                .cfg
                .tiers
                .iter()
                .enumerate()
                .map(|(i, tr)| TierRoute {
                    boundary: tr.boundary,
                    gamma: clamp_gamma(
                        tr.boundary,
                        self.cfg.tiers.get(i + 1).map(|t| t.boundary),
                        (tr.gamma * tighten).min(2.0),
                    ),
                })
                .collect(),
            enable_cr: self.cfg.enable_cr,
        };
        let out = route_ladder(
            &tight,
            &mut self.scratch,
            text,
            max_output_tokens,
            category,
            actual_prompt,
            est_total,
        );
        self.absorb_outcome(&out);
        finish_request(out, max_output_tokens, est_total, t0.elapsed().as_secs_f64())
    }

    /// Re-route a request whose first attempt died downstream (a replica
    /// crash killed it in flight). The decision runs the same ladder as
    /// [`Gateway::route`] against the gateway's *current* config — which
    /// under failover may differ from the one the first attempt saw — but
    /// it is accounting-neutral: **no** EMA estimator update (the first
    /// attempt already folded this prompt's true token count in — a retry
    /// storm must not double-weight its text), **no** `n_routed`/
    /// compression counters, and **no** route-memo interaction (the memo
    /// keyed the first decision; re-reserving would evict live entries).
    /// Only `n_rerouted` moves. Pinned by the retry-storm regression in
    /// `tests/gateway_concurrency.rs`.
    pub fn reroute_failed(&mut self, text: &str, max_output_tokens: u32) -> RoutedRequest {
        let t0 = std::time::Instant::now();
        let category = classify(text);
        let est_prompt = self
            .estimator
            .estimate_prompt_tokens(text.len(), category);
        let est_total = est_prompt + max_output_tokens;
        let actual_prompt = count_tokens(text);
        let out = route_ladder(
            &self.cfg,
            &mut self.scratch,
            text,
            max_output_tokens,
            category,
            actual_prompt,
            est_total,
        );
        self.n_rerouted += 1;
        finish_request(out, max_output_tokens, est_total, t0.elapsed().as_secs_f64())
    }

    /// Route one request through a [`RouteCache`]. Hits replay the stored
    /// outcome byte-for-byte — including the EMA update from the cached
    /// uncompressed token count — so estimator state, counters, and every
    /// `RoutedRequest` field except `gateway_s` are bit-identical to
    /// [`Gateway::route`] on the same request sequence.
    pub fn route_cached(
        &mut self,
        cache: &mut RouteCache,
        text: &str,
        max_output_tokens: u32,
    ) -> RoutedRequest {
        let t0 = std::time::Instant::now();
        cache.ensure_config(self.cfg.fingerprint());
        let category = classify(text);
        let est_prompt = self
            .estimator
            .estimate_prompt_tokens(text.len(), category);
        let est_total = est_prompt + max_output_tokens;
        let key = CacheKey::new(
            text,
            max_output_tokens,
            self.cfg.decision_signature(est_total),
        );
        if let Lookup::Hit(out) = cache.lookup(key, text) {
            self.estimator.update(text.len(), out.actual_prompt, category);
            self.absorb_outcome(&out);
            return finish_request(
                out,
                max_output_tokens,
                est_total,
                t0.elapsed().as_secs_f64(),
            );
        }
        // Miss (or a stale pending reservation): compute and (re)fill.
        let actual_prompt = count_tokens(text);
        self.estimator.update(text.len(), actual_prompt, category);
        let out = route_ladder(
            &self.cfg,
            &mut self.scratch,
            text,
            max_output_tokens,
            category,
            actual_prompt,
            est_total,
        );
        if let Some(slot) = cache.reserve(key, text, usize::MAX) {
            cache.fill(slot, out.clone());
        }
        self.absorb_outcome(&out);
        finish_request(out, max_output_tokens, est_total, t0.elapsed().as_secs_f64())
    }

    /// Route a batch of `(text, max_output_tokens)` requests, streaming
    /// each result to `sink` **as soon as it is routed** — so a dispatcher
    /// can enqueue request k while request k+1 is still being compressed
    /// (no head-of-line blocking on the batch). Routing semantics are
    /// identical to calling [`Gateway::route`] per item in order; the
    /// batch form keeps one warm pass over the shared scratch per due
    /// window (§Perf) and is what `coordinator::serve` uses.
    pub fn route_batch_with(
        &mut self,
        batch: &[(&str, u32)],
        sink: impl FnMut(usize, RoutedRequest),
    ) {
        self.route_batch_with_opts(batch, 1, None, sink);
    }

    /// [`Gateway::route_batch_with`] with explicit concurrency and
    /// memoization. `workers` = 0 picks an automatic shard count (like
    /// [`crate::util::par::workers_for`]); any effective count ≤ 1 runs
    /// the serial loop. Outputs (every `RoutedRequest` field except the
    /// wall-clock `gateway_s`), counters, estimator state, and cache
    /// stats are bit-identical for every worker count and cache setting
    /// (`tests/gateway_concurrency.rs`); `sink` is always called in
    /// request order on the sharded path, since results are reassembled
    /// before emission.
    pub fn route_batch_with_opts(
        &mut self,
        batch: &[(&str, u32)],
        workers: usize,
        mut cache: Option<&mut RouteCache>,
        mut sink: impl FnMut(usize, RoutedRequest),
    ) {
        let w = shard::effective_workers(workers, batch.len());
        if w <= 1 {
            for (k, &(text, max_output)) in batch.iter().enumerate() {
                let routed = match cache.as_deref_mut() {
                    Some(c) => self.route_cached(c, text, max_output),
                    None => self.route(text, max_output),
                };
                sink(k, routed);
            }
            return;
        }
        shard::route_batch_sharded(self, batch, w, cache, sink);
    }

    /// Collecting wrapper over [`Gateway::route_batch_with`].
    pub fn route_batch(&mut self, batch: &[(&str, u32)]) -> Vec<RoutedRequest> {
        let mut out = Vec::with_capacity(batch.len());
        self.route_batch_with(batch, |_, routed| out.push(routed));
        out
    }

    /// Realized alpha' (Eq. 14 diagnostics): the fraction of traffic kept
    /// out of the full-context tier.
    pub fn alpha_prime(&self) -> f64 {
        let total: u64 = self.n_routed.iter().sum();
        if total == 0 {
            0.0
        } else {
            (total - self.n_routed_long()) as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::corpus::{self, CorpusConfig};
    use crate::util::rng::Rng;

    fn gw(b_short: u32, enable_cr: bool) -> Gateway {
        Gateway::new(GatewayConfig::two_tier(b_short, 1.5, enable_cr))
    }

    fn doc(tokens: u32, rng: &mut Rng) -> String {
        corpus::generate_document(
            &CorpusConfig {
                target_tokens: tokens,
                ..Default::default()
            },
            rng,
        )
    }

    #[test]
    fn short_requests_route_short_untouched() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(1);
        let text = doc(500, &mut rng);
        let r = g.route(&text, 64);
        assert_eq!(r.tier, 0);
        assert!(!r.compressed);
        assert_eq!(r.text, text);
    }

    #[test]
    fn borderline_prose_is_compressed_short() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(2);
        // ~2600 tokens: inside (2048, 3072].
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 128);
        assert_eq!(r.tier, 0, "decision for {} est tokens", r.estimated_l_total);
        assert!(r.compressed);
        // Hard OOM guarantee at the gateway: prompt + output <= B.
        assert!(
            r.prompt_tokens + r.max_output_tokens <= 2048,
            "{} + {} > 2048",
            r.prompt_tokens,
            r.max_output_tokens
        );
        assert_eq!(g.n_compressed, 1);
    }

    #[test]
    fn borderline_code_goes_long() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(3);
        let code = corpus::generate_code(2600, &mut rng);
        let r = g.route(&code, 128);
        assert_eq!(r.tier, 1);
        assert!(!r.compressed);
        assert_eq!(g.n_compressed, 0);
    }

    #[test]
    fn cr_disabled_sends_borderline_long() {
        let mut g = gw(2048, false);
        let mut rng = Rng::new(4);
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 128);
        assert_eq!(r.tier, 1);
    }

    #[test]
    fn genuinely_long_routes_long() {
        let mut g = gw(1024, true);
        let mut rng = Rng::new(5);
        let text = doc(4000, &mut rng); // far above gamma * B
        let r = g.route(&text, 128);
        assert_eq!(r.tier, 1);
    }

    #[test]
    fn output_budget_exceeding_boundary_fails_safe() {
        let mut g = gw(1024, true);
        let mut rng = Rng::new(6);
        // Small prompt, huge output budget: estimated L_total lands in the
        // band but L_out >= B, so no compression can make it fit.
        let text = doc(300, &mut rng);
        let r = g.route(&text, 1100);
        assert!(r.estimated_l_total > 1024 && r.estimated_l_total <= 1536);
        assert_eq!(r.tier, 1);
        assert_eq!(g.n_compress_failed, 1);
    }

    #[test]
    fn stats_track_routing() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let t = doc(400, &mut rng);
            g.route(&t, 32);
        }
        let long_text = doc(8000, &mut rng);
        g.route(&long_text, 32);
        assert_eq!(g.n_routed_short(), 5);
        assert_eq!(g.n_routed_long(), 1);
        assert!((g.alpha_prime() - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn route_batch_matches_sequential_route() {
        let mut rng = Rng::new(9);
        let texts: Vec<String> = (0..6)
            .map(|i| doc(if i % 2 == 0 { 400 } else { 2600 }, &mut rng))
            .collect();
        let batch: Vec<(&str, u32)> = texts.iter().map(|t| (t.as_str(), 64)).collect();
        let mut g1 = gw(2048, true);
        let routed = g1.route_batch(&batch);
        let mut g2 = gw(2048, true);
        for (item, r1) in batch.iter().zip(&routed) {
            let r2 = g2.route(item.0, item.1);
            assert_eq!(r1.tier, r2.tier);
            assert_eq!(r1.text, r2.text);
            assert_eq!(r1.compressed, r2.compressed);
            assert_eq!(r1.prompt_tokens, r2.prompt_tokens);
        }
        assert_eq!(g1.n_compressed, g2.n_compressed);
        assert_eq!(g1.n_routed, g2.n_routed);
    }

    #[test]
    fn gateway_latency_is_recorded() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(8);
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 64);
        assert!(r.gateway_s > 0.0 && r.gateway_s < 1.0);
    }

    #[test]
    fn three_tier_routing_lands_in_middle_tier() {
        // Boundaries at 512 and 2048: a ~1000-token doc skips tier 0 and
        // lands naturally in tier 1; a ~2600-token prose doc compresses
        // down into tier 1 (band of the 2048 boundary).
        let mut g = Gateway::new(GatewayConfig::tiered(&[512, 2048], 1.5, true));
        assert_eq!(g.cfg.n_tiers(), 3);
        let mut rng = Rng::new(10);
        let mid = doc(1000, &mut rng);
        let r = g.route(&mid, 64);
        assert_eq!(r.tier, 1);
        assert!(!r.compressed);
        let borderline = doc(2600, &mut rng);
        let r = g.route(&borderline, 64);
        assert_eq!(r.tier, 1, "est {}", r.estimated_l_total);
        assert!(r.compressed);
        assert!(r.prompt_tokens + r.max_output_tokens <= 2048);
        let huge = doc(6000, &mut rng);
        let r = g.route(&huge, 64);
        assert_eq!(r.tier, 2);
        assert_eq!(g.n_routed, vec![0, 2, 1]);
    }

    #[test]
    fn fingerprint_moves_with_every_config_input() {
        let base = GatewayConfig::tiered(&[512, 2048], 1.5, true);
        let fp = base.fingerprint();
        assert_eq!(fp, base.clone().fingerprint());
        let mut b = base.clone();
        b.tiers[0].boundary = 513;
        assert_ne!(fp, b.fingerprint());
        let mut g = base.clone();
        g.tiers[1].gamma = 1.25;
        assert_ne!(fp, g.fingerprint());
        let mut cr = base.clone();
        cr.enable_cr = false;
        assert_ne!(fp, cr.fingerprint());
    }

    #[test]
    fn decision_signature_separates_gate_regions() {
        let cfg = GatewayConfig::two_tier(1000, 1.5, true);
        // Regions: <=1000, (1000, 1500], >1500.
        assert_eq!(cfg.decision_signature(900), cfg.decision_signature(1000));
        assert_eq!(cfg.decision_signature(1001), cfg.decision_signature(1500));
        assert_eq!(cfg.decision_signature(1501), cfg.decision_signature(9000));
        assert_ne!(cfg.decision_signature(1000), cfg.decision_signature(1001));
        assert_ne!(cfg.decision_signature(1500), cfg.decision_signature(1501));
    }

    #[test]
    fn cached_routing_is_identical_to_cold() {
        let mut rng = Rng::new(11);
        let texts: Vec<String> = (0..4)
            .map(|i| doc(if i % 2 == 0 { 400 } else { 2600 }, &mut rng))
            .collect();
        // Replay the 4 docs 3 times: 8 misses counted once, then hits.
        let seq: Vec<&String> = (0..12).map(|i| &texts[i % 4]).collect();
        let mut cold = gw(2048, true);
        let mut warm = gw(2048, true);
        let mut cache = RouteCache::new(64);
        for text in seq {
            let a = cold.route(text, 64);
            let b = warm.route_cached(&mut cache, text, 64);
            assert_eq!(a.tier, b.tier);
            assert_eq!(a.text, b.text);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.compressed, b.compressed);
            assert_eq!(a.estimated_l_total, b.estimated_l_total);
        }
        assert_eq!(cold.metrics(), warm.metrics());
        assert_eq!(
            cold.estimator.c_hat_bits(),
            warm.estimator.c_hat_bits(),
            "EMA state must not drift on cache hits"
        );
        assert!(cache.stats.hits >= 8, "replays should hit: {:?}", cache.stats);
    }
}
