//! The gateway: classify → estimate → route, with C&R inline (paper §2.1,
//! §5.1), generalized to K-tier fleets. This is the request-path
//! embodiment of the planner's boundaries: a request takes the first tier
//! whose boundary fits it; a borderline compressible request just above
//! tier i's boundary is extractively compressed to `T_c = B_i − L_out`
//! and routed *into tier i* (the "virtual pool", per boundary); everything
//! else falls through to the last (full-context) tier. With a single
//! boundary this is the paper's two-pool gateway, decision for decision.

use crate::compress::extractive::compress_with;
use crate::compress::gate::{clamp_gamma, compression_budget, gate, GateDecision};
use crate::compress::scratch::CompressScratch;
use crate::compress::tokenizer::count_tokens;
use crate::router::classify::classify;
use crate::router::estimator::TokenEstimator;
use crate::workload::request::Category;

/// One routing boundary: requests at or below `boundary` fit this tier;
/// the C&R band reaches up to `gamma * boundary`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierRoute {
    pub boundary: u32,
    pub gamma: f64,
}

/// Gateway configuration: the planner's output boundaries applied at the
/// request path. `tiers` holds the K−1 boundaries in ascending order; the
/// implicit last tier takes everything above them.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    pub tiers: Vec<TierRoute>,
    /// Compression enabled (false = plain pool routing baseline).
    pub enable_cr: bool,
}

impl GatewayConfig {
    /// The paper's two-pool configuration: one boundary, one band.
    pub fn two_tier(b_short: u32, gamma: f64, enable_cr: bool) -> Self {
        GatewayConfig {
            tiers: vec![TierRoute {
                boundary: b_short,
                gamma,
            }],
            enable_cr,
        }
    }

    /// K-tier configuration with one shared gamma at every boundary. Each
    /// boundary's band is clamped at the next boundary up
    /// ([`clamp_gamma`]): traffic in `(B_{i+1}, gamma B_i]` belongs to a
    /// tier the planner's adjacent-transfer accounting never moves, so
    /// the router must not claim it either.
    pub fn tiered(boundaries: &[u32], gamma: f64, enable_cr: bool) -> Self {
        assert!(!boundaries.is_empty());
        GatewayConfig {
            tiers: boundaries
                .iter()
                .enumerate()
                .map(|(i, &boundary)| TierRoute {
                    boundary,
                    gamma: clamp_gamma(boundary, boundaries.get(i + 1).copied(), gamma),
                })
                .collect(),
            enable_cr,
        }
    }

    /// Number of tiers K (boundaries + the implicit last tier).
    pub fn n_tiers(&self) -> usize {
        self.tiers.len() + 1
    }

    /// The first boundary (the paper's `B_short` at K = 2).
    pub fn b_short(&self) -> u32 {
        self.tiers[0].boundary
    }
}

/// A routed request, ready for an engine pool.
#[derive(Clone, Debug)]
pub struct RoutedRequest {
    /// Destination tier index (0 = densest pool, K−1 = full-context pool).
    pub tier: usize,
    /// Final prompt text (compressed when C&R fired).
    pub text: String,
    /// Actual prompt tokens of `text` (shared tokenizer).
    pub prompt_tokens: u32,
    pub max_output_tokens: u32,
    pub category: Category,
    /// Estimated L_total used for the routing decision.
    pub estimated_l_total: u32,
    pub compressed: bool,
    /// Gateway processing time for this request, seconds.
    pub gateway_s: f64,
}

/// The stateful gateway (one per deployment; EMA state is shared across
/// requests exactly as in §2.1).
///
/// §Perf: the gateway owns a [`CompressScratch`] so every C&R compression
/// reuses the same parse/score/select buffers — steady-state routing
/// performs no heap allocation beyond the returned `RoutedRequest`.
#[derive(Debug)]
pub struct Gateway {
    pub cfg: GatewayConfig,
    pub estimator: TokenEstimator,
    scratch: CompressScratch,
    /// Requests routed to each tier (len K).
    pub n_routed: Vec<u64>,
    pub n_compressed: u64,
    pub n_compress_failed: u64,
}

impl Gateway {
    pub fn new(cfg: GatewayConfig) -> Self {
        let k = cfg.n_tiers();
        Gateway {
            cfg,
            estimator: TokenEstimator::default(),
            scratch: CompressScratch::new(),
            n_routed: vec![0; k],
            n_compressed: 0,
            n_compress_failed: 0,
        }
    }

    /// Requests routed to the densest tier.
    pub fn n_routed_short(&self) -> u64 {
        self.n_routed[0]
    }

    /// Requests routed to the full-context (last) tier.
    pub fn n_routed_long(&self) -> u64 {
        *self.n_routed.last().expect("at least two tiers")
    }

    /// Route one request. The returned `text` is what the engine prefills.
    pub fn route(&mut self, text: &str, max_output_tokens: u32) -> RoutedRequest {
        let t0 = std::time::Instant::now();
        let category = classify(text);
        let est_prompt = self
            .estimator
            .estimate_prompt_tokens(text.len(), category);
        let est_total = est_prompt + max_output_tokens;

        // Post-hoc EMA update from the true count (the engine tokenizes
        // anyway; the estimate must be cheap, the update can be exact).
        let actual_prompt = count_tokens(text);
        self.estimator.update(text.len(), actual_prompt, category);

        let last_tier = self.cfg.tiers.len();
        let mut routed = None;
        for tier in 0..last_tier {
            let tr = self.cfg.tiers[tier]; // Copy: no borrow held across the mutating compress call
            let gamma = if self.cfg.enable_cr { tr.gamma } else { 1.0 };
            // Re-clamp at use: `cfg.tiers` is public, so a hand-built
            // config may carry unclamped gammas (no-op otherwise, and
            // identical to the pre-refactor path at K = 2).
            let gamma = clamp_gamma(
                tr.boundary,
                self.cfg.tiers.get(tier + 1).map(|t| t.boundary),
                gamma,
            );
            match gate(est_total, tr.boundary, gamma, category) {
                GateDecision::RouteShort => {
                    routed = Some(RoutedRequest {
                        tier,
                        text: text.to_string(),
                        prompt_tokens: actual_prompt,
                        max_output_tokens,
                        category,
                        estimated_l_total: est_total,
                        compressed: false,
                        gateway_s: 0.0,
                    });
                    break;
                }
                GateDecision::CompressAndRoute => {
                    match compression_budget(tr.boundary, max_output_tokens) {
                        Some(budget) => {
                            let c = compress_with(&mut self.scratch, text, budget);
                            if c.ok {
                                self.n_compressed += 1;
                                routed = Some(RoutedRequest {
                                    tier,
                                    prompt_tokens: count_tokens(&c.text),
                                    text: c.text,
                                    max_output_tokens,
                                    category,
                                    estimated_l_total: est_total,
                                    compressed: true,
                                    gateway_s: 0.0,
                                });
                                break;
                            }
                            // Compression failed: fall through to the next
                            // tier up (at K = 2, the long pool).
                            self.n_compress_failed += 1;
                        }
                        None => {
                            self.n_compress_failed += 1;
                        }
                    }
                }
                GateDecision::BandButUnsafe | GateDecision::RouteLong => {}
            }
        }
        let routed = routed.unwrap_or_else(|| RoutedRequest {
            tier: last_tier,
            text: text.to_string(),
            prompt_tokens: actual_prompt,
            max_output_tokens,
            category,
            estimated_l_total: est_total,
            compressed: false,
            gateway_s: 0.0,
        });
        self.n_routed[routed.tier] += 1;
        RoutedRequest {
            gateway_s: t0.elapsed().as_secs_f64(),
            ..routed
        }
    }

    /// Route a batch of `(text, max_output_tokens)` requests, streaming
    /// each result to `sink` **as soon as it is routed** — so a dispatcher
    /// can enqueue request k while request k+1 is still being compressed
    /// (no head-of-line blocking on the batch). Routing semantics are
    /// identical to calling [`Gateway::route`] per item in order; the
    /// batch form keeps one warm pass over the shared scratch per due
    /// window (§Perf) and is what `coordinator::serve` uses.
    pub fn route_batch_with(
        &mut self,
        batch: &[(&str, u32)],
        mut sink: impl FnMut(usize, RoutedRequest),
    ) {
        for (k, &(text, max_output)) in batch.iter().enumerate() {
            sink(k, self.route(text, max_output));
        }
    }

    /// Collecting wrapper over [`Gateway::route_batch_with`].
    pub fn route_batch(&mut self, batch: &[(&str, u32)]) -> Vec<RoutedRequest> {
        let mut out = Vec::with_capacity(batch.len());
        self.route_batch_with(batch, |_, routed| out.push(routed));
        out
    }

    /// Realized alpha' (Eq. 14 diagnostics): the fraction of traffic kept
    /// out of the full-context tier.
    pub fn alpha_prime(&self) -> f64 {
        let total: u64 = self.n_routed.iter().sum();
        if total == 0 {
            0.0
        } else {
            (total - self.n_routed_long()) as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::corpus::{self, CorpusConfig};
    use crate::util::rng::Rng;

    fn gw(b_short: u32, enable_cr: bool) -> Gateway {
        Gateway::new(GatewayConfig::two_tier(b_short, 1.5, enable_cr))
    }

    fn doc(tokens: u32, rng: &mut Rng) -> String {
        corpus::generate_document(
            &CorpusConfig {
                target_tokens: tokens,
                ..Default::default()
            },
            rng,
        )
    }

    #[test]
    fn short_requests_route_short_untouched() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(1);
        let text = doc(500, &mut rng);
        let r = g.route(&text, 64);
        assert_eq!(r.tier, 0);
        assert!(!r.compressed);
        assert_eq!(r.text, text);
    }

    #[test]
    fn borderline_prose_is_compressed_short() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(2);
        // ~2600 tokens: inside (2048, 3072].
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 128);
        assert_eq!(r.tier, 0, "decision for {} est tokens", r.estimated_l_total);
        assert!(r.compressed);
        // Hard OOM guarantee at the gateway: prompt + output <= B.
        assert!(
            r.prompt_tokens + r.max_output_tokens <= 2048,
            "{} + {} > 2048",
            r.prompt_tokens,
            r.max_output_tokens
        );
        assert_eq!(g.n_compressed, 1);
    }

    #[test]
    fn borderline_code_goes_long() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(3);
        let code = corpus::generate_code(2600, &mut rng);
        let r = g.route(&code, 128);
        assert_eq!(r.tier, 1);
        assert!(!r.compressed);
        assert_eq!(g.n_compressed, 0);
    }

    #[test]
    fn cr_disabled_sends_borderline_long() {
        let mut g = gw(2048, false);
        let mut rng = Rng::new(4);
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 128);
        assert_eq!(r.tier, 1);
    }

    #[test]
    fn genuinely_long_routes_long() {
        let mut g = gw(1024, true);
        let mut rng = Rng::new(5);
        let text = doc(4000, &mut rng); // far above gamma * B
        let r = g.route(&text, 128);
        assert_eq!(r.tier, 1);
    }

    #[test]
    fn output_budget_exceeding_boundary_fails_safe() {
        let mut g = gw(1024, true);
        let mut rng = Rng::new(6);
        // Small prompt, huge output budget: estimated L_total lands in the
        // band but L_out >= B, so no compression can make it fit.
        let text = doc(300, &mut rng);
        let r = g.route(&text, 1100);
        assert!(r.estimated_l_total > 1024 && r.estimated_l_total <= 1536);
        assert_eq!(r.tier, 1);
        assert_eq!(g.n_compress_failed, 1);
    }

    #[test]
    fn stats_track_routing() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let t = doc(400, &mut rng);
            g.route(&t, 32);
        }
        let long_text = doc(8000, &mut rng);
        g.route(&long_text, 32);
        assert_eq!(g.n_routed_short(), 5);
        assert_eq!(g.n_routed_long(), 1);
        assert!((g.alpha_prime() - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn route_batch_matches_sequential_route() {
        let mut rng = Rng::new(9);
        let texts: Vec<String> = (0..6)
            .map(|i| doc(if i % 2 == 0 { 400 } else { 2600 }, &mut rng))
            .collect();
        let batch: Vec<(&str, u32)> = texts.iter().map(|t| (t.as_str(), 64)).collect();
        let mut g1 = gw(2048, true);
        let routed = g1.route_batch(&batch);
        let mut g2 = gw(2048, true);
        for (item, r1) in batch.iter().zip(&routed) {
            let r2 = g2.route(item.0, item.1);
            assert_eq!(r1.tier, r2.tier);
            assert_eq!(r1.text, r2.text);
            assert_eq!(r1.compressed, r2.compressed);
            assert_eq!(r1.prompt_tokens, r2.prompt_tokens);
        }
        assert_eq!(g1.n_compressed, g2.n_compressed);
        assert_eq!(g1.n_routed, g2.n_routed);
    }

    #[test]
    fn gateway_latency_is_recorded() {
        let mut g = gw(2048, true);
        let mut rng = Rng::new(8);
        let text = doc(2600, &mut rng);
        let r = g.route(&text, 64);
        assert!(r.gateway_s > 0.0 && r.gateway_s < 1.0);
    }

    #[test]
    fn three_tier_routing_lands_in_middle_tier() {
        // Boundaries at 512 and 2048: a ~1000-token doc skips tier 0 and
        // lands naturally in tier 1; a ~2600-token prose doc compresses
        // down into tier 1 (band of the 2048 boundary).
        let mut g = Gateway::new(GatewayConfig::tiered(&[512, 2048], 1.5, true));
        assert_eq!(g.cfg.n_tiers(), 3);
        let mut rng = Rng::new(10);
        let mid = doc(1000, &mut rng);
        let r = g.route(&mid, 64);
        assert_eq!(r.tier, 1);
        assert!(!r.compressed);
        let borderline = doc(2600, &mut rng);
        let r = g.route(&borderline, 64);
        assert_eq!(r.tier, 1, "est {}", r.estimated_l_total);
        assert!(r.compressed);
        assert!(r.prompt_tokens + r.max_output_tokens <= 2048);
        let huge = doc(6000, &mut rng);
        let r = g.route(&huge, 64);
        assert_eq!(r.tier, 2);
        assert_eq!(g.n_routed, vec![0, 2, 1]);
    }
}
