//! Degraded-capacity failover for the tier router (chaos response).
//!
//! When a tier's live capacity drops below a watermark of its target, its
//! routing boundary is *removed* from the effective ladder rather than
//! zeroed: zeroing would make [`crate::compress::gate::clamp_gamma`]
//! collapse the band below it, while removal gives exactly the spill the
//! paper's boundary structure implies —
//!
//! * **up-spill** (always admissible): traffic that natively fit the
//!   degraded tier's window falls through to the next longer-context tier
//!   (a longer window always fits it);
//! * **down-spill** (through the existing C&R ladder only): the boundary
//!   *below* the degraded tier keeps its band and gets a tightened
//!   (boosted, clamp-capped) gamma, so borderline compressible traffic is
//!   pulled down across the boundary instead of burdening the longer tier.
//!
//! A degraded **last** tier cannot be dropped (it is the ladder's
//! fallback); it only gets the gamma boost at the boundary below.
//! Hysteresis ([`FailoverState::observe`]) separates the degrade and
//! recover watermarks so capacity flapping near the threshold does not
//! flap the routing config. With no tier degraded the effective config is
//! the original, verbatim — failover wired in but never engaged is
//! bit-identical to no failover at all (tested here and in the DES).

use crate::router::gateway::{GatewayConfig, TierRoute};

/// Failover policy knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailoverConfig {
    /// A tier degrades when live/target capacity falls strictly below
    /// this fraction.
    pub spill_watermark: f64,
    /// A degraded tier recovers when live/target rises to at least this
    /// fraction (must be >= `spill_watermark` for hysteresis).
    pub recover_watermark: f64,
    /// Multiplier applied to the gamma of a boundary whose next tier up
    /// is degraded (down-spill tightening), capped at 2.0 and re-clamped
    /// against the next boundary by the router as usual.
    pub gamma_boost: f64,
}

impl Default for FailoverConfig {
    fn default() -> Self {
        FailoverConfig {
            spill_watermark: 0.7,
            recover_watermark: 0.9,
            gamma_boost: 1.25,
        }
    }
}

/// Per-tier hysteretic degradation tracker.
#[derive(Clone, Debug, Default)]
pub struct FailoverState {
    degraded: Vec<bool>,
}

impl FailoverState {
    pub fn new(k: usize) -> Self {
        FailoverState {
            degraded: vec![false; k],
        }
    }

    /// Feed one tier's live (serving) and target capacity; returns the
    /// tier's updated degraded flag. The two watermarks form the
    /// hysteresis band: a healthy tier degrades only below
    /// `spill_watermark`, a degraded one recovers only at or above
    /// `recover_watermark`. A zero-target tier is never degraded.
    pub fn observe(&mut self, tier: usize, live: u64, target: u64, cfg: &FailoverConfig) -> bool {
        if tier >= self.degraded.len() {
            self.degraded.resize(tier + 1, false);
        }
        if target == 0 {
            self.degraded[tier] = false;
            return false;
        }
        let frac = live as f64 / target as f64;
        let d = self.degraded[tier];
        self.degraded[tier] = if d {
            frac < cfg.recover_watermark
        } else {
            frac < cfg.spill_watermark
        };
        self.degraded[tier]
    }

    pub fn degraded(&self) -> &[bool] {
        &self.degraded
    }

    pub fn any_degraded(&self) -> bool {
        self.degraded.iter().any(|&d| d)
    }
}

/// Derive the effective routing vectors under a degradation mask.
///
/// `boundaries`/`gammas` are the K−1 original boundary windows and bands;
/// `degraded` has one flag per tier (len K; shorter is zero-extended).
/// Returns `(eff_boundaries, eff_gammas, tier_map)` where `tier_map[e]`
/// is the *original* tier index effective tier `e` routes to
/// (`tier_map.len() == eff_boundaries.len() + 1`). With no degraded tier
/// the originals come back verbatim and the map is the identity.
pub fn effective_routes(
    boundaries: &[u32],
    gammas: &[f64],
    degraded: &[bool],
    gamma_boost: f64,
) -> (Vec<u32>, Vec<f64>, Vec<usize>) {
    assert_eq!(boundaries.len(), gammas.len());
    let k = boundaries.len() + 1;
    let is_down = |t: usize| degraded.get(t).copied().unwrap_or(false);
    if (0..k).all(|t| !is_down(t)) {
        return (
            boundaries.to_vec(),
            gammas.to_vec(),
            (0..k).collect(),
        );
    }
    // Kept tiers: every healthy tier, plus the last tier unconditionally
    // (it is the ladder's fallback and has no boundary to drop).
    let kept: Vec<usize> = (0..k).filter(|&t| t == k - 1 || !is_down(t)).collect();
    let mut eff_b = Vec::with_capacity(kept.len() - 1);
    let mut eff_g = Vec::with_capacity(kept.len() - 1);
    for &t in &kept[..kept.len() - 1] {
        // Boost this boundary's band when the original next tier up is
        // degraded (including a degraded-but-kept last tier): borderline
        // traffic compresses down instead of spilling up. The cap keeps
        // the boost inside the gate's sane range; the router re-clamps
        // against the next *effective* boundary as always.
        let boosted = is_down(t + 1);
        let g = if boosted {
            (gammas[t] * gamma_boost).min(2.0)
        } else {
            gammas[t]
        };
        eff_b.push(boundaries[t]);
        eff_g.push(g);
    }
    (eff_b, eff_g, kept)
}

/// [`effective_routes`] lifted to a [`GatewayConfig`]: the degraded
/// config has fewer `TierRoute`s, so its fingerprint differs from the
/// healthy one and the route memo invalidates itself on the flip (and
/// again on recovery). Routed tiers must be mapped back through the
/// returned map before enqueueing to physical pools.
pub fn effective_gateway_config(
    cfg: &GatewayConfig,
    degraded: &[bool],
    fo: &FailoverConfig,
) -> (GatewayConfig, Vec<usize>) {
    let boundaries: Vec<u32> = cfg.tiers.iter().map(|t| t.boundary).collect();
    let gammas: Vec<f64> = cfg.tiers.iter().map(|t| t.gamma).collect();
    let (eff_b, eff_g, map) =
        effective_routes(&boundaries, &gammas, degraded, fo.gamma_boost);
    let eff = GatewayConfig {
        tiers: eff_b
            .iter()
            .zip(&eff_g)
            .map(|(&boundary, &gamma)| TierRoute { boundary, gamma })
            .collect(),
        enable_cr: cfg.enable_cr,
    };
    (eff, map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_mask_is_identity() {
        let b = vec![512u32, 2048];
        let g = vec![1.5, 1.4];
        let (eb, eg, map) = effective_routes(&b, &g, &[false, false, false], 1.25);
        assert_eq!(eb, b);
        assert_eq!(eg, g);
        assert_eq!(map, vec![0, 1, 2]);
        // Empty mask too (zero-extension).
        let (eb2, eg2, map2) = effective_routes(&b, &g, &[], 1.25);
        assert_eq!((eb2, eg2, map2), (b, g, vec![0, 1, 2]));
    }

    #[test]
    fn degraded_middle_tier_drops_its_boundary() {
        let b = vec![512u32, 2048];
        let g = vec![1.5, 1.4];
        // Tier 1 down: its 2048 boundary vanishes (up-spill of (512, 2048]
        // traffic to tier 2), and boundary 0's gamma is boosted so
        // borderline traffic down-spills into tier 0 through C&R.
        let (eb, eg, map) = effective_routes(&b, &g, &[false, true, false], 1.25);
        assert_eq!(eb, vec![512]);
        assert_eq!(eg, vec![(1.5f64 * 1.25).min(2.0)]);
        assert_eq!(map, vec![0, 2]);
    }

    #[test]
    fn degraded_first_tier_up_spills() {
        let b = vec![512u32, 2048];
        let g = vec![1.5, 1.4];
        let (eb, eg, map) = effective_routes(&b, &g, &[true, false, false], 1.25);
        assert_eq!(eb, vec![2048]);
        assert_eq!(eg, vec![1.4], "no boost: tier above the cut is healthy");
        assert_eq!(map, vec![1, 2]);
    }

    #[test]
    fn degraded_last_tier_is_kept_with_boosted_band() {
        let b = vec![512u32, 2048];
        let g = vec![1.5, 1.4];
        let (eb, eg, map) = effective_routes(&b, &g, &[false, false, true], 1.5);
        assert_eq!(eb, b, "the fallback tier cannot be dropped");
        assert_eq!(eg[0], 1.5, "boundary below a healthy tier is untouched");
        assert_eq!(eg[1], (1.4f64 * 1.5).min(2.0));
        assert_eq!(map, vec![0, 1, 2]);
    }

    #[test]
    fn everything_degraded_routes_to_fallback_only() {
        let b = vec![512u32, 2048];
        let g = vec![1.5, 1.4];
        let (eb, _eg, map) = effective_routes(&b, &g, &[true, true, true], 1.25);
        assert!(eb.is_empty());
        assert_eq!(map, vec![2]);
    }

    #[test]
    fn observe_hysteresis() {
        let cfg = FailoverConfig::default();
        let mut st = FailoverState::new(2);
        // 10 live of 10 target: healthy.
        assert!(!st.observe(0, 10, 10, &cfg));
        // 7/10 = 0.7 is *at* the spill watermark — not degraded (strict).
        assert!(!st.observe(0, 7, 10, &cfg));
        // 6/10 < 0.7: degrade.
        assert!(st.observe(0, 6, 10, &cfg));
        // Back to 8/10 = 0.8: inside the hysteresis band, stays degraded.
        assert!(st.observe(0, 8, 10, &cfg));
        assert!(st.any_degraded());
        // 9/10 >= 0.9: recover.
        assert!(!st.observe(0, 9, 10, &cfg));
        assert!(!st.any_degraded());
        // Zero-target tiers never degrade (a drained tier is not a fault).
        assert!(!st.observe(1, 0, 0, &cfg));
    }

    #[test]
    fn gateway_config_fingerprint_flips_with_degradation() {
        let cfg = GatewayConfig::tiered(&[512, 2048], 1.5, true);
        let fo = FailoverConfig::default();
        let (healthy, map_h) =
            effective_gateway_config(&cfg, &[false, false, false], &fo);
        assert_eq!(healthy.fingerprint(), cfg.fingerprint());
        assert_eq!(map_h, vec![0, 1, 2]);
        let (degraded, map_d) =
            effective_gateway_config(&cfg, &[false, true, false], &fo);
        assert_ne!(degraded.fingerprint(), cfg.fingerprint());
        assert_eq!(degraded.n_tiers(), 2);
        assert_eq!(map_d, vec![0, 2]);
    }
}
