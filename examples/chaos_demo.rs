//! Failure injection end to end: the diurnal Azure scenario from
//! `autoscale_demo.rs`, now with the standard fault plan armed — per-replica
//! crash–restart churn plus a whole-tier-0 outage dropped right on the
//! diurnal peak (t = 62 s..74 s of the ~100 s horizon). Two policies run
//! under the identical fault trace:
//!
//! * **none** — the planner's exact sizing, no spares, no failover: the
//!   outage epochs blow the queue-wait SLO.
//! * **N+1 + failover** — one spare per provisioned tier
//!   (`input.redundancy = vec![1]`) and degraded-capacity spill
//!   (`FailoverConfig`): while tier 0 is below its capacity watermark the
//!   gateway routes its traffic up the ladder, and the hysteresis band
//!   restores the planned boundaries once replicas are back.
//!
//! Like the other files in `examples/`, this is library-API reference
//! source (the crate lives in `rust/`, which declares no example targets).
//! The runnable equivalent is the CLI command CI smokes:
//!
//! ```bash
//! cargo run --release --manifest-path rust/Cargo.toml -- \
//!     autoscale --workload azure --arrivals diurnal:amp=0.6,period=300 \
//!     --chaos examples/configs/chaos_plan.json \
//!     --redundancy 1 --failover --out CHAOS_epochs.json
//! ```

use fleetopt::fleetsim::{simulate_autoscale_chaos, AutoscaleConfig, ChaosOpts, FaultPlan};
use fleetopt::metrics::EpochMetrics;
use fleetopt::planner::{plan_spec_sweep_gamma, PlanInput};
use fleetopt::router::failover::FailoverConfig;
use fleetopt::workload::arrivals::RateModel;
use fleetopt::workload::traces;

fn main() -> anyhow::Result<()> {
    let w = traces::azure();
    let model = RateModel::Diurnal {
        base: 400.0,
        amp: 0.6,
        period_s: 300.0,
        phase: 0.0,
    };
    let n = 40_000;
    let faults = FaultPlan::from_file("examples/configs/chaos_plan.json")?;
    let outage = faults.outages[0];
    let cfg = AutoscaleConfig {
        epoch_s: 4.0,
        window_s: 8.0,
        provision_delay_s: 2.0,
        ..AutoscaleConfig::default()
    };

    // Policy 1: exact sizing, crashes land on a fleet with zero slack.
    let input = PlanInput::new(w.clone(), model.rate_hint());
    let spec = input.gpu.fleet_spec(&[w.b_short]);
    let init = plan_spec_sweep_gamma(&input, &spec)?;
    let bare = ChaosOpts {
        faults: Some(faults.clone()),
        failover: None,
    };
    let rep_none =
        simulate_autoscale_chaos(&w, model.clone(), n, &input, init, &cfg, 42, &bare);

    // Policy 2: N+1 spares sized through the planner's lower bound, plus
    // cross-tier spill while tier 0 sits below its capacity watermark.
    let mut input_k = input.clone();
    input_k.redundancy = vec![1];
    let init_k = plan_spec_sweep_gamma(&input_k, &spec)?;
    let chaos = ChaosOpts {
        faults: Some(faults),
        failover: Some(FailoverConfig::default()),
    };
    let rep = simulate_autoscale_chaos(&w, model, n, &input_k, init_k, &cfg, 42, &chaos);

    for e in &rep.epochs {
        let hit = e.t_start_s < outage.start_s + outage.duration_s
            && e.t_end_s > outage.start_s;
        let marker = if hit { "  <- tier-0 outage" } else { "" };
        println!("{}{}", e.summary_line(), marker);
    }
    println!(
        "\nchaos trace: {} crash(es), {} in-flight kill(s) -> {} retry(ies), \
         {} route(s) spilled across the degraded boundary",
        rep.crashes, rep.killed_in_flight, rep.retries_total, rep.spilled
    );
    println!(
        "none       : slo-ok {:3.0}% of {} epochs, {:.2} GPU-hours (${:.2})",
        rep_none.slo_ok_frac * 100.0,
        rep_none.epochs.len(),
        rep_none.gpu_hours,
        rep_none.cost
    );
    println!(
        "n+1 + fo   : slo-ok {:3.0}% of {} epochs, {:.2} GPU-hours (${:.2}, \
         +{:.1}% for the spares)",
        rep.slo_ok_frac * 100.0,
        rep.epochs.len(),
        rep.gpu_hours,
        rep.cost,
        (rep.cost / rep_none.cost - 1.0) * 100.0
    );
    std::fs::write("chaos_epochs.json", EpochMetrics::series_to_json(&rep.epochs))?;
    println!("per-epoch series written to chaos_epochs.json");
    Ok(())
}
