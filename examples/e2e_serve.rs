//! End-to-end driver (DESIGN.md E10): serve a real batched workload through
//! the full three-layer stack — Rust gateway + two-pool coordinator (L3),
//! AOT-compiled JAX transformer (L2) with Pallas attention kernels (L1)
//! executing via PJRT — and compare homogeneous vs pool-routing vs
//! pool-routing + Compress-and-Route on the same trace.
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md §E10.
//!
//! ```bash
//! cargo run --release --example e2e_serve
//! ```

use fleetopt::compress::corpus::{self, CorpusConfig};
use fleetopt::coordinator::{serve, ServeConfig, ServeItem};
use fleetopt::router::GatewayConfig;
use fleetopt::util::rng::Rng;

/// Live-scale boundary: short pool window is 256 tokens (DESIGN.md §4);
/// B_short leaves room for the output budget.
const B_SHORT: u32 = 224;
const GAMMA: f64 = 1.5;

fn make_workload(n: usize, rate: f64, seed: u64) -> Vec<ServeItem> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exp(rate);
            // Live-scaled mix mirroring an Archetype-I/II CDF: most
            // requests well under B, a meaningful borderline band, a thin
            // genuinely-long tail.
            let target = match i % 10 {
                0..=6 => rng.range(40, 160) as u32,
                7 | 8 => rng.range(235, 330) as u32, // borderline (<= gamma*B)
                _ => rng.range(420, 800) as u32,     // genuinely long
            };
            ServeItem {
                text: corpus::generate_document(
                    &CorpusConfig {
                        target_tokens: target,
                        ..Default::default()
                    },
                    &mut rng,
                ),
                max_output: 16,
                arrival_offset_s: t,
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let Some(dir) = fleetopt::experiments::artifacts_dir() else {
        anyhow::bail!("artifacts not built: run `make artifacts` first");
    };
    // ~2.5 req/s offered vs ~4-5 req/s capacity: below saturation, so TTFT
    // reflects prefill/decode rather than pure queueing.
    let n = 45;
    let items = make_workload(n, 2.5, 7);
    println!("serving {n} requests through 3 fleet configurations...\n");

    // 1. "Homogeneous": everything in the long pool (B = 0 boundary;
    //    nothing fits below one token, so all traffic routes long).
    let homo = ServeConfig::two_tier(GatewayConfig::two_tier(1, 1.0, false), 0, 2);
    // 2. Pool routing: two pools, hard boundary, no compression.
    let pr = ServeConfig::two_tier(GatewayConfig::two_tier(B_SHORT, GAMMA, false), 1, 1);
    // 3. Pool routing + C&R: borderline prose compressed below B.
    let cr = ServeConfig::two_tier(GatewayConfig::two_tier(B_SHORT, GAMMA, true), 1, 1);

    for (name, cfg) in [("homogeneous", homo), ("pool-routing", pr), ("PR + C&R", cr)] {
        let mut report = serve(&dir, &cfg, items.clone(), 1.0)?;
        println!("== {name} (replicas {:?}) ==", cfg.replicas);
        for tier in &mut report.tiers {
            println!("  {}", tier.summary());
        }
        println!(
            "  routed short/long = {}/{} | compressed = {} | throughput = {:.1} req/s | gateway = {:.2} ms/req | wall = {:.1}s",
            report.n_routed_short(),
            report.n_routed_long(),
            report.n_compressed,
            report.throughput_rps,
            report.mean_gateway_s * 1e3,
            report.duration_s,
        );
        assert_eq!(report.completed() as usize, n, "all requests must complete");
        println!();
    }
    println!(
        "note: with equal replica counts, C&R shifts borderline traffic into\n\
         the dense short pool (more KV slots per replica) — the live-path\n\
         mirror of the paper's beta*p_c*(1-1/rho) GPU saving."
    );
    Ok(())
}
