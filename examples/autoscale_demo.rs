//! Autoscaling control loop end to end: a diurnal Azure trace through the
//! DES with the replanning controller, against the static worst-case plan
//! and the per-epoch oracle.
//!
//! Like the other files in `examples/`, this is library-API reference
//! source (the crate lives in `rust/`, which declares no example
//! targets). The runnable equivalents are the CLI commands CI smokes:
//!
//! ```bash
//! cargo run --release --manifest-path rust/Cargo.toml -- \
//!     autoscale --workload azure --arrivals diurnal:amp=0.6,period=300
//! cargo run --release --manifest-path rust/Cargo.toml -- \
//!     autoscale --workload azure \
//!     --arrivals schedule:examples/configs/diurnal_schedule.json
//! cargo run --release --manifest-path rust/Cargo.toml -- tables --only 9
//! ```

use fleetopt::fleetsim::{simulate_autoscale, AutoscaleConfig};
use fleetopt::metrics::EpochMetrics;
use fleetopt::planner::{plan_spec_sweep_gamma, PlanInput};
use fleetopt::workload::arrivals::RateModel;
use fleetopt::workload::traces;

fn main() -> anyhow::Result<()> {
    let w = traces::azure();
    let model = RateModel::Diurnal {
        base: 400.0,
        amp: 0.6,
        period_s: 300.0,
        phase: 0.0,
    };
    let n = 40_000;
    let spec = PlanInput::new(w.clone(), 1.0).gpu.fleet_spec(&[w.b_short]);

    // Static worst case: provision the peak once, never touch it.
    let input_peak = PlanInput::new(w.clone(), model.peak_rate());
    let static_plan = plan_spec_sweep_gamma(&input_peak, &spec)?;
    let cfg = AutoscaleConfig {
        epoch_s: 4.0,
        window_s: 8.0,
        provision_delay_s: 2.0,
        ..AutoscaleConfig::default()
    };
    let mut cfg_static = cfg.clone();
    cfg_static.replanning = false;
    let rep_static =
        simulate_autoscale(&w, model.clone(), n, &input_peak, static_plan, &cfg_static, 42);

    // The online control loop, cold-started at the t = 0 rate.
    let input0 = PlanInput::new(w.clone(), model.rate_hint());
    let init = plan_spec_sweep_gamma(&input0, &spec)?;
    let rep = simulate_autoscale(&w, model, n, &input0, init, &cfg, 42);

    for e in &rep.epochs {
        println!("{}", e.summary_line());
    }
    println!(
        "\nautoscale  : {:.2} GPU-hours (${:.2}), slo-ok {:.0}% of {} epochs",
        rep.gpu_hours,
        rep.cost,
        rep.slo_ok_frac * 100.0,
        rep.epochs.len()
    );
    println!(
        "static-peak: {:.2} GPU-hours (${:.2}) — the bill for ignoring the trough",
        rep_static.gpu_hours, rep_static.cost
    );
    std::fs::write("autoscale_epochs.json", EpochMetrics::series_to_json(&rep.epochs))?;
    println!("per-epoch series written to autoscale_epochs.json");
    Ok(())
}
