//! Compress-and-Route close-up: run the §5.2 extractive pipeline on a
//! borderline RAG-style prompt, show the stage scores, the hard OOM
//! guarantee, and the Table-7 fidelity metrics (including the
//! model-embedding cosine when artifacts are built).
//!
//! ```bash
//! cargo run --release --example compress_demo
//! ```

use fleetopt::compress::corpus::{generate_borderline, generate_code};
use fleetopt::compress::doc::Document;
use fleetopt::compress::extractive::compress_doc;
use fleetopt::compress::gate::{compression_budget, gate, GateDecision};
use fleetopt::compress::scoring::score;
use fleetopt::compress::tokenizer::count_tokens;
use fleetopt::compress::{fidelity, GateDecision as _GD};
use fleetopt::router::classify;
use fleetopt::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let _ = _GD::RouteShort; // silence unused import lint on re-export check
    let b_short = 8192u32;
    let gamma = 1.5;
    let l_out = 512u32;
    let mut rng = Rng::new(42);

    // A borderline prompt: 8K-12K tokens of RAG-ish prose.
    let doc_text = generate_borderline(b_short, gamma, &mut rng);
    let l_total = count_tokens(&doc_text) + l_out;
    let category = classify(&doc_text);
    println!(
        "prompt: {} tokens (+{} output budget) category={:?}",
        count_tokens(&doc_text),
        l_out,
        category
    );

    // Gate (paper §5.1-5.2).
    let decision = gate(l_total, b_short, gamma, category);
    println!("gate decision: {decision:?}");
    assert_eq!(decision, GateDecision::CompressAndRoute);

    // Stage scores for the first few sentences.
    let doc = Document::parse(&doc_text);
    let scores = score(&doc);
    println!("\nfirst 6 sentences (textrank/position/tfidf/novelty -> composite):");
    for i in 0..6.min(doc.n_sentences()) {
        println!(
            "  [{i}] {:.2}/{:.2}/{:.2}/{:.2} -> {:.3}  {:.60}...",
            scores.textrank[i],
            scores.position[i],
            scores.tfidf[i],
            scores.novelty[i],
            scores.composite[i],
            doc.sentences[i]
        );
    }

    // Compress to T_c = B_short - L_out (Eq. 15).
    let budget = compression_budget(b_short, l_out).unwrap();
    let t0 = std::time::Instant::now();
    let c = compress_doc(&doc, budget);
    println!(
        "\ncompressed {} -> {} tokens (budget {budget}) in {:.1} ms; ok={}",
        c.original_tokens,
        c.compressed_tokens,
        t0.elapsed().as_secs_f64() * 1e3,
        c.ok
    );
    assert!(c.compressed_tokens + l_out <= b_short, "OOM guarantee violated!");
    println!("hard OOM guarantee: {} + {} <= {}", c.compressed_tokens, l_out, b_short);

    // Fidelity (Table 7 metrics).
    let f = fidelity::measure(&doc_text, &c.text);
    println!(
        "fidelity: ROUGE-L recall={:.3} TF-IDF cosine={:.3} reduction={:.1}%",
        f.rouge_l_recall,
        f.tfidf_cosine,
        f.token_reduction * 100.0
    );
    if let Some(dir) = fleetopt::experiments::artifacts_dir() {
        let rt = fleetopt::runtime::ModelRuntime::load(dir)?;
        let ea = rt.embed_text(&doc_text)?;
        let eb = rt.embed_text(&c.text)?;
        println!(
            "embedding cosine (L1/L2 stack, BERTScore proxy): {:.3}",
            fleetopt::runtime::cosine(&ea, &eb)
        );
    } else {
        println!("(embedding cosine skipped: run `make artifacts`)");
    }

    // The safety gate: code is never compressed.
    let code = generate_code(10_000, &mut rng);
    let code_cat = classify(&code);
    let code_decision = gate(count_tokens(&code) + l_out, b_short, gamma, code_cat);
    println!("\ncode prompt: category={code_cat:?} -> {code_decision:?} (never compressed)");
    assert_eq!(code_decision, GateDecision::BandButUnsafe);
    Ok(())
}
