//! Sharded gateway admission + route memo close-up (PR 8): replay a
//! duplicate-heavy borderline trace through `route_batch_with_opts`,
//! compare serial/uncached against sharded/memoized, and print the shard
//! count, per-stage admission latency, and cache hit rate.
//!
//! Like the other files in `examples/`, this is library-API reference
//! source (the crate lives in `rust/`, which declares no example
//! targets). The runnable equivalent is the serve CLI:
//!
//! ```bash
//! cargo run --release --manifest-path rust/Cargo.toml -- \
//!     serve --requests 200 --gateway-workers 0 --route-cache-cap 1024
//! cargo run --release --manifest-path rust/Cargo.toml -- \
//!     serve --trace my_trace.jsonl --gateway-workers 4
//! ```

use std::time::Instant;

use fleetopt::compress::corpus;
use fleetopt::router::memo::RouteCache;
use fleetopt::router::{effective_workers, Gateway, GatewayConfig};
use fleetopt::util::rng::Rng;
use fleetopt::workload::traces;

fn main() {
    // A templated production-style trace: 8 unique borderline prompts,
    // each replayed 25 times (round-robin), plus the agent-heavy two-pool
    // config so most of them cross the C&R band and compress.
    let w = traces::agent_heavy();
    let cfg = GatewayConfig::two_tier(w.b_short, w.gamma, true);
    let mut rng = Rng::new(0x9A7E);
    let unique: Vec<String> = (0..8)
        .map(|_| corpus::generate_borderline_for(&w, &mut rng))
        .collect();
    let batch: Vec<(&str, u32)> = (0..200)
        .map(|k| (unique[k % unique.len()].as_str(), 512u32))
        .collect();

    // Baseline: the serial uncached loop (workers=1, no cache).
    let mut serial_gw = Gateway::new(cfg.clone());
    let t0 = Instant::now();
    let serial_out = serial_gw.route_batch(&batch);
    let serial_s = t0.elapsed().as_secs_f64();

    // Sharded + memoized: auto worker count, 1024-entry route cache.
    let workers = effective_workers(0, batch.len());
    let mut gw = Gateway::new(cfg);
    let mut cache = RouteCache::new(1024);
    let mut out = Vec::with_capacity(batch.len());
    let t0 = Instant::now();
    gw.route_batch_with_opts(&batch, 0, Some(&mut cache), |_, r| out.push(r));
    let fast_s = t0.elapsed().as_secs_f64();

    // The determinism contract: everything but wall-clock `gateway_s` is
    // bit-identical to the serial uncached loop.
    assert_eq!(serial_out.len(), out.len());
    for (a, b) in serial_out.iter().zip(&out) {
        assert_eq!(a.tier, b.tier);
        assert_eq!(a.text, b.text);
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
        assert_eq!(a.estimated_l_total, b.estimated_l_total);
        assert_eq!(a.compressed, b.compressed);
    }
    assert_eq!(serial_gw.metrics(), gw.metrics());
    assert_eq!(serial_gw.estimator.c_hat_bits(), gw.estimator.c_hat_bits());

    println!("gateway admission pipeline — {} requests, {} unique prompts", batch.len(), unique.len());
    println!(
        "  serial uncached : {:7.1} req/s",
        batch.len() as f64 / serial_s
    );
    println!(
        "  sharded + memo  : {:7.1} req/s ({workers} workers, {:.2}x)",
        batch.len() as f64 / fast_s,
        serial_s / fast_s.max(1e-9)
    );
    println!(
        "  route cache     : {} / {} entries | {:.1}% hits ({} hits, {} misses, {} evictions)",
        cache.len(),
        cache.capacity(),
        cache.stats.hit_rate() * 100.0,
        cache.stats.hits,
        cache.stats.misses,
        cache.stats.evictions
    );
    if let Some(t) = gw.last_shard {
        println!(
            "  last batch      : workers={} features={:.2}ms fold={:.2}ms ladder={:.2}ms emit={:.2}ms",
            t.workers,
            t.features_s * 1e3,
            t.fold_s * 1e3,
            t.ladder_s * 1e3,
            t.emit_s * 1e3
        );
    }
    println!("  identity        : outputs, counters, and estimator bits match the serial loop");
}
