//! Quickstart: plan a minimum-cost fleet from a workload CDF in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fleetopt::planner::{plan_fleet, plan_homogeneous, sweep_gamma, PlanInput};
use fleetopt::workload::traces;

fn main() -> anyhow::Result<()> {
    // 1. Pick a workload CDF (here: the Azure-trace-calibrated generator)
    //    and an arrival rate.
    let workload = traces::azure();
    let input = PlanInput::new(workload.clone(), 1000.0); // 1,000 req/s

    // 2. Baselines: homogeneous 64K fleet and plain pool routing.
    let homo = plan_homogeneous(&input)?;
    let pr = plan_fleet(&input, workload.b_short, 1.0)?;

    // 3. FleetOpt: sweep gamma at the boundary; C&R makes the optimal
    //    boundary achievable (paper Algorithm 1).
    let best = sweep_gamma(&input, workload.b_short)?;

    println!("workload          : {}", workload.name);
    println!("alpha / beta      : {:.3} / {:.3}", workload.alpha(), workload.beta());
    println!("homogeneous fleet : {} GPUs (${:.0}K/yr)", homo.total_gpus(), homo.cost_yr / 1e3);
    println!(
        "pool routing      : {} GPUs ({:.1}% saved)",
        pr.total_gpus(),
        100.0 * (1.0 - pr.cost_yr / homo.cost_yr)
    );
    println!(
        "fleetopt (g*={:.1}) : {} GPUs = {} short + {} long ({:.1}% saved)",
        best.gamma,
        best.total_gpus(),
        best.short.n_gpus,
        best.long.n_gpus,
        100.0 * (1.0 - best.cost_yr / homo.cost_yr)
    );
    println!(
        "pool utilization  : short {:.3}, long {:.3} (cap 0.85)",
        best.short.rho_ana(),
        best.long.rho_ana()
    );
    Ok(())
}
