//! Planner deep-dive: the full Algorithm-1 sweep, the marginal-cost (FOC)
//! profile behind Proposition 1, and the mu_l-recalibration ablation the
//! paper calls "critical" (§6).
//!
//! ```bash
//! cargo run --release --example planner_sweep
//! ```

use fleetopt::planner::marginal::foc_profile;
use fleetopt::planner::{
    candidate_boundaries, plan_fleet, plan_fleet_no_recalibration, sweep_full, PlanInput,
};
use fleetopt::workload::traces;

fn main() -> anyhow::Result<()> {
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        println!("\n=== {} ===", w.name);

        // Full (B, gamma) sweep.
        let t0 = std::time::Instant::now();
        let (best, grid) = sweep_full(&input)?;
        println!(
            "optimum: B*={} gamma*={:.1} -> {} GPUs (${:.0}K/yr); {} cells in {:.1} ms",
            best.b_short,
            best.gamma,
            best.total_gpus(),
            best.cost_yr / 1e3,
            grid.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );

        // Proposition 1: the marginal-cost gap across boundaries. Negative
        // everywhere => the short pool is marginally cheaper at every
        // feasible B, and the planner raises the *effective* boundary via
        // gamma instead (the C&R virtual pool).
        let cands = candidate_boundaries(&input);
        let prof = foc_profile(&input, &cands, 1.0);
        println!("FOC gap (c_s dn_s/dl - c_l dn_l/dl), $/hr per req/s:");
        for (b, gap) in prof {
            println!("  B={b:6}: {gap:+.3}");
        }

        // The recalibration ablation: skipping the post-compression mu_l
        // recalibration underestimates the long pool (over-promises
        // savings) — exactly the failure mode §6 warns about.
        let correct = plan_fleet(&input, w.b_short, 2.0)?;
        let wrong = plan_fleet_no_recalibration(&input, w.b_short, 2.0)?;
        println!(
            "recalibration ablation at gamma=2.0: correct n_l={}, naive n_l={} ({}%)",
            correct.long.n_gpus,
            wrong.long.n_gpus,
            if correct.long.n_gpus > 0 {
                format!(
                    "{:+.0}",
                    100.0 * (wrong.long.n_gpus as f64 / correct.long.n_gpus as f64 - 1.0)
                )
            } else {
                "n/a".into()
            }
        );
    }
    Ok(())
}
