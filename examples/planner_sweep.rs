//! Planner deep-dive: the full Algorithm-1 sweep, the marginal-cost (FOC)
//! profile behind Proposition 1, the mu_l-recalibration ablation the
//! paper calls "critical" (§6), the K-tier boundary sweeps behind Table 8,
//! a 3-tier fleet loaded from `examples/configs/three_tier.json`, and the
//! heterogeneous-SKU planner: a mixed-SKU plan from
//! `examples/configs/sku_catalog.json` printed next to the single-SKU one.
//!
//! ```bash
//! cargo run --release --example planner_sweep
//! ```

use fleetopt::config::{FleetSpec, SkuCatalog};
use fleetopt::planner::marginal::foc_profile;
use fleetopt::planner::{
    anytime_search, candidate_boundaries, plan_fleet, plan_fleet_no_recalibration,
    plan_spec_sweep_gamma, sweep_full, sweep_tiered, sweep_tiered_pruned, AnytimeConfig,
    CalibCache, Deadline, PlanInput,
};
use fleetopt::util::json::Json;
use fleetopt::workload::traces::{self, Workload};

fn main() -> anyhow::Result<()> {
    for w in traces::all() {
        let input = PlanInput::new(w.clone(), 1000.0);
        println!("\n=== {} ===", w.name);

        // Full (B, gamma) sweep.
        let t0 = std::time::Instant::now();
        let (best, grid) = sweep_full(&input)?;
        println!(
            "optimum: B*={} gamma*={:.1} -> {} GPUs (${:.0}K/yr); {} cells in {:.1} ms",
            best.b_short,
            best.gamma,
            best.total_gpus(),
            best.cost_yr / 1e3,
            grid.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );

        // Proposition 1: the marginal-cost gap across boundaries. Negative
        // everywhere => the short pool is marginally cheaper at every
        // feasible B, and the planner raises the *effective* boundary via
        // gamma instead (the C&R virtual pool).
        let cands = candidate_boundaries(&input);
        let prof = foc_profile(&input, &cands, 1.0);
        println!("FOC gap (c_s dn_s/dl - c_l dn_l/dl), $/hr per req/s:");
        for (b, gap) in prof {
            println!("  B={b:6}: {gap:+.3}");
        }

        // The recalibration ablation: skipping the post-compression mu_l
        // recalibration underestimates the long pool (over-promises
        // savings) — exactly the failure mode §6 warns about.
        let correct = plan_fleet(&input, w.b_short, 2.0)?;
        let wrong = plan_fleet_no_recalibration(&input, w.b_short, 2.0)?;
        println!(
            "recalibration ablation at gamma=2.0: correct n_l={}, naive n_l={} ({}%)",
            correct.long.n_gpus,
            wrong.long.n_gpus,
            if correct.long.n_gpus > 0 {
                format!(
                    "{:+.0}",
                    100.0 * (wrong.long.n_gpus as f64 / correct.long.n_gpus as f64 - 1.0)
                )
            } else {
                "n/a".into()
            }
        );

        // K-tier boundary sweeps (Table 8): does a third/fourth context
        // tier pay beyond the paper's two pools?
        for k in [3usize, 4] {
            let t0 = std::time::Instant::now();
            let (kbest, grid) = sweep_tiered(&input, k)?;
            println!(
                "K={k}: B*={:?} gpus={:?} -> ${:.0}K/yr ({} cells in {:.1} ms)",
                kbest.boundaries(),
                kbest.gpu_counts(),
                kbest.cost_yr / 1e3,
                grid.len(),
                t0.elapsed().as_secs_f64() * 1e3,
            );
        }
    }

    // A 3-tier fleet + workload from a JSON config, end-to-end.
    let config_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/configs/three_tier.json"
    );
    if std::path::Path::new(config_path).exists() {
        println!("\n=== three_tier.json ===");
        let w = Workload::from_config_file(config_path)?;
        let text = std::fs::read_to_string(config_path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{config_path}: {e}"))?;
        let input = PlanInput::new(w, 1000.0);
        let spec = FleetSpec::from_json(j.expect("tiers"), &input.gpu)?;
        let best = plan_spec_sweep_gamma(&input, &spec)?;
        println!(
            "fixed tiers {:?}: gammas={:?} gpus={:?} -> ${:.0}K/yr",
            best.boundaries(),
            best.gammas,
            best.gpu_counts(),
            best.cost_yr / 1e3,
        );
    }

    // Heterogeneous SKUs: plan the azure K=3 fleet twice — pinned to the
    // base A100 profile, then over `sku_catalog.json` with the anytime
    // planner under a 50 ms budget — and print them side by side. The
    // catalog contains the base SKU, so mixed never costs more.
    let catalog_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/configs/sku_catalog.json"
    );
    if std::path::Path::new(catalog_path).exists() {
        println!("\n=== sku_catalog.json (azure, K=3) ===");
        let catalog = SkuCatalog::from_file(catalog_path)?;
        let input = PlanInput::new(traces::azure(), 1000.0);
        let (single, _) = sweep_tiered_pruned(&input, 3, &CalibCache::new())?;
        println!(
            "single-SKU (a100):  B*={:?} gpus={:?} -> ${:.0}K/yr",
            single.boundaries(),
            single.gpu_counts(),
            single.cost_yr / 1e3,
        );
        let res = anytime_search(
            &input,
            3,
            Some(&catalog),
            &CalibCache::new(),
            Deadline::after_ms(50),
            &AnytimeConfig::default(),
        )?;
        let skus: Vec<&str> = res
            .plan
            .spec
            .tiers
            .iter()
            .map(|t| match t.sku_index() {
                Some(i) => catalog.skus[i].name.as_str(),
                None => "a100",
            })
            .collect();
        println!(
            "mixed-SKU catalog:  B*={:?} gpus={:?} skus={skus:?} -> ${:.0}K/yr \
             ({} cells, gap {:.2}%, exact={}, saving {:+.1}%)",
            res.plan.boundaries(),
            res.plan.gpu_counts(),
            res.plan.cost_yr / 1e3,
            res.cells_evaluated,
            res.bound_gap_pct,
            res.exact,
            (1.0 - res.plan.cost_yr / single.cost_yr) * 100.0,
        );
    }
    Ok(())
}
